//! Incremental private decoding over a secret-shared KV cache.
//!
//! The paper's headline motivation is autoregressive NLG ("SMPC-based GPT-2
//! takes 25+ minutes per token"), yet re-running the full three-party
//! forward pass per generated token makes every token cost a whole-sequence
//! inference. A [`DecoderSession`] instead owns per-layer
//! [`crate::protocols::layer::LayerKvCache`]s — `[K]`/`[Ṽ]` sharings that
//! are **never reconstructed** — and drives single-token forwards through
//! [`crate::protocols::layer::transformer_layer_step`]: every step moves
//! `(1, ·)` rows through the same `Π_PP*` protocols, cutting per-token
//! online communication ~8× at `n_ctx = 64` (DESIGN.md §KV-cache).
//!
//! Cost attribution: the session splits its [`CostLedger`] into a one-time
//! **setup** phase (fixed-operand correlation openings,
//! `OpClass::Correlation`), a **cold-prefill** phase (absorbing the
//! prompt) and a **warm-decode** phase (generated tokens), so benches and
//! serving metrics can report the split per token. Per-step cost is
//! position-independent — the cache has a fixed `(n_ctx, d)` shape and
//! unwritten rows are masked — so one warm step is representative of all
//! of them. With fixed-operand correlations (DESIGN.md §Fixed-operand
//! correlations, on by default) the session-fixed π₁/π₁ᵀ operands and the
//! write-once K cache ride session masks opened once, cutting warm-step
//! communication a further ~2.5× beyond the KV cache itself.

use crate::data::greedy_regular_token;
use crate::model::ModelKind;
use crate::net::CostLedger;
use crate::protocols::layer::{self, LayerKvCache};
use crate::protocols::{adaptation, embedding};
use crate::tensor::FloatTensor;
use crate::Result;

use super::CentaurEngine;

/// Result of one streamed generation: the tokens plus the phase-split cost.
pub struct GenOutcome {
    /// Generated continuation (prompt excluded).
    pub tokens: Vec<u32>,
    /// One-time session-correlation setup cost (the fixed-operand masked
    /// openings, `OpClass::Correlation`); empty when correlations are off.
    pub setup: CostLedger,
    /// Online cost of absorbing the prompt (cold prefill).
    pub prefill: CostLedger,
    /// Online cost of the generated steps (warm decode).
    pub decode: CostLedger,
}

/// Merge the three session phases into one ledger (single definition
/// shared by [`GenOutcome::total`] and [`DecoderSession::total_cost`]).
fn merged_phases(setup: &CostLedger, prefill: &CostLedger, decode: &CostLedger) -> CostLedger {
    setup.merged(prefill).merged(decode)
}

impl GenOutcome {
    /// Setup + prefill + decode merged into one ledger.
    pub fn total(&self) -> CostLedger {
        merged_phases(&self.setup, &self.prefill, &self.decode)
    }
}

/// An in-progress incremental decode over one engine (GPT-2 only).
///
/// The session borrows the engine mutably: its KV cache is bound to the
/// engine's permutations (`[Ṽ]` is pre-permuted by the session-fixed π₁),
/// and all communication lands in the engine's ledger. P1's observations
/// accumulate in the engine's [`super::views::Views`] across the whole
/// session, so `engine.leaks()` after a multi-step generate audits every
/// step at once.
pub struct DecoderSession<'e> {
    eng: &'e mut CentaurEngine,
    kv: Vec<LayerKvCache>,
    pos: usize,
    setup: CostLedger,
    prefill: CostLedger,
    decode: CostLedger,
    decode_steps: u64,
    last_step: CostLedger,
    last_logits: FloatTensor,
}

impl<'e> DecoderSession<'e> {
    /// Start a session and absorb `prompt` (cold prefill). The prompt must
    /// be non-empty and fit the context window.
    ///
    /// With fixed-operand correlations enabled (the default,
    /// [`super::EngineOptions::decode_correlations`]), session start deals
    /// one correlation bundle per family per layer — pool-first, generated
    /// on demand on a cold start — and performs the one-time masked
    /// openings of π₁/π₁ᵀ, charged to the separate `setup` ledger
    /// (`OpClass::Correlation`) so warm-step ledgers stay clean.
    pub fn new(eng: &'e mut CentaurEngine, prompt: &[u32]) -> Result<Self> {
        anyhow::ensure!(eng.cfg.kind == ModelKind::Gpt2, "incremental decode needs a decoder model");
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(prompt.len() <= eng.cfg.n_ctx, "prompt longer than n_ctx");
        eng.mpc.net.reset();
        let mut kv = Vec::with_capacity(eng.cfg.layers);
        for _ in 0..eng.cfg.layers {
            if eng.decode_correlations {
                let corr =
                    layer::deal_kv_correlations(&mut eng.mpc, &eng.cfg, &eng.pi1_sh, &eng.pi1_t_sh)?;
                kv.push(LayerKvCache::with_correlations(eng.cfg.n_ctx, eng.cfg.d, corr));
            } else {
                kv.push(LayerKvCache::new(eng.cfg.n_ctx, eng.cfg.d));
            }
        }
        let setup = eng.mpc.net.ledger.clone();
        eng.views.clear();
        let mut sess = DecoderSession {
            eng,
            kv,
            pos: 0,
            setup,
            prefill: CostLedger::new(),
            decode: CostLedger::new(),
            decode_steps: 0,
            last_step: CostLedger::new(),
            last_logits: FloatTensor::zeros(1, 1),
        };
        for &t in prompt {
            sess.absorb_phase(t, false)?;
        }
        Ok(sess)
    }

    /// Tokens absorbed so far (prompt + generated).
    pub fn position(&self) -> usize {
        self.pos
    }

    /// Remaining context capacity.
    pub fn remaining(&self) -> usize {
        self.eng.cfg.n_ctx - self.pos
    }

    /// Next-token logits `(1, vocab)` for the last absorbed position.
    pub fn logits(&self) -> &FloatTensor {
        &self.last_logits
    }

    /// Absorb one externally chosen token (teacher forcing / sampling done
    /// client-side), charged to the warm-decode phase.
    pub fn absorb(&mut self, token: u32) -> Result<()> {
        self.absorb_phase(token, true)
    }

    /// Greedily pick the next token from the current logits (specials are
    /// never emitted), absorb it, and return it.
    ///
    /// The emitted token is absorbed immediately so the cache always
    /// covers every emitted token — the session stays resumable (the
    /// client can keep stepping, or [`DecoderSession::absorb`] more input,
    /// at any point). The price is that a session discarded right after
    /// its last step has paid one absorb whose logits were never read.
    pub fn step_greedy(&mut self) -> Result<u32> {
        let next = greedy_regular_token(self.last_logits.row(0));
        self.absorb_phase(next, true)?;
        Ok(next)
    }

    /// One single-token forward through the full three-party protocol.
    fn absorb_phase(&mut self, token: u32, decode_phase: bool) -> Result<()> {
        anyhow::ensure!(self.pos < self.eng.cfg.n_ctx, "context window exhausted");
        anyhow::ensure!((token as usize) < self.eng.cfg.vocab, "token {token} out of vocab");
        let pos = self.pos;
        let eng = &mut *self.eng;
        eng.mpc.net.reset();
        let logits_sh = {
            let mut ctx = layer::ProtoCtx {
                mpc: &mut eng.mpc,
                backend: eng.backend.as_mut(),
                views: &mut eng.views,
                fast_sim: eng.fast_sim,
                round_batching: eng.round_batching,
            };
            let mut x_pi = embedding::pp_embedding_at(&mut ctx, &eng.pm, token, pos)?;
            if ctx.round_batching {
                // Batched schedule: the last layer fuses the final Π_PPLN
                // into its reshare flight, so adaptation is just the
                // communication-free LM head plus the logits return.
                let last = eng.pm.layers.len() - 1;
                for (i, pl) in eng.pm.layers[..last].iter().enumerate() {
                    x_pi = layer::transformer_layer_step(
                        &mut ctx,
                        &eng.cfg,
                        pl,
                        &eng.pi1_sh,
                        &eng.pi1_t_sh,
                        &x_pi,
                        &mut self.kv[i],
                        pos,
                        i,
                    )?;
                }
                let (_, h_pi) = layer::transformer_layer_step_final(
                    &mut ctx,
                    &eng.cfg,
                    &eng.pm.layers[last],
                    &eng.pi1_sh,
                    &eng.pi1_t_sh,
                    &x_pi,
                    &mut self.kv[last],
                    pos,
                    last,
                    eng.pm.final_ln_g.as_deref().expect("gpt weights"),
                    eng.pm.final_ln_b.as_deref().expect("gpt weights"),
                )?;
                adaptation::pp_lm_head_gpt2(&mut ctx, &eng.pm, &h_pi)?
            } else {
                for (i, pl) in eng.pm.layers.iter().enumerate() {
                    x_pi = layer::transformer_layer_step(
                        &mut ctx,
                        &eng.cfg,
                        pl,
                        &eng.pi1_sh,
                        &eng.pi1_t_sh,
                        &x_pi,
                        &mut self.kv[i],
                        pos,
                        i,
                    )?;
                }
                adaptation::pp_adaptation_gpt2(&mut ctx, &eng.pm, &x_pi)?
            }
        };
        let logits = adaptation::return_to_client(&mut eng.mpc, &logits_sh)?;
        let step = eng.mpc.net.ledger.clone();
        if decode_phase {
            self.decode.merge(&step);
            self.decode_steps += 1;
        } else {
            self.prefill.merge(&step);
        }
        self.last_step = step;
        self.last_logits = logits;
        self.pos += 1;
        Ok(())
    }

    /// One-time session setup cost (fixed-operand correlation openings;
    /// empty when correlations are disabled).
    pub fn setup_cost(&self) -> &CostLedger {
        &self.setup
    }

    /// Per-layer fixed-operand opening counters
    /// `(π₁ openings, π₁ᵀ openings, K rows opened)` — the security census
    /// asserts exactly one π₁-side opening per session per layer. Empty
    /// when correlations are disabled.
    pub fn correlation_openings(&self) -> Vec<(u64, u64, u64)> {
        self.kv
            .iter()
            .filter_map(|kv| {
                kv.correlations()
                    .map(|c| (c.ppp.openings(), c.append.openings(), c.scores.openings()))
            })
            .collect()
    }

    /// Per-layer unused correlation bundles left
    /// `(ppp, append, scores)` — exhausting any of them makes further
    /// absorbs error instead of reusing a mask.
    pub fn correlation_uses_left(&self) -> Vec<(usize, usize, usize)> {
        self.kv
            .iter()
            .filter_map(|kv| {
                kv.correlations()
                    .map(|c| (c.ppp.uses_left(), c.append.uses_left(), c.scores.uses_left()))
            })
            .collect()
    }

    /// Online cost of the cold-prefill phase (prompt absorption).
    pub fn prefill_cost(&self) -> &CostLedger {
        &self.prefill
    }

    /// Online cost of the warm-decode phase (generated tokens).
    pub fn decode_cost(&self) -> &CostLedger {
        &self.decode
    }

    /// Warm-decode absorbs so far (generated tokens; excludes prefill).
    pub fn decode_steps(&self) -> u64 {
        self.decode_steps
    }

    /// Warm-decode protocol rounds per generated token — the WAN latency
    /// lever (`rounds · RTT` dominates decode under the WAN profiles); 0
    /// before the first warm step. Per-step rounds are
    /// position-independent, so this is exact, not an average.
    pub fn decode_rounds_per_token(&self) -> u64 {
        if self.decode_steps == 0 {
            0
        } else {
            self.decode.rounds_total() / self.decode_steps
        }
    }

    /// Per-[`crate::net::OpClass`] round breakdown of the most recent
    /// step — the table the round-budget harness pins golden values
    /// against (`rust/tests/round_budget.rs`).
    pub fn last_step_rounds_by_class(&self) -> [(crate::net::OpClass, u64); 8] {
        self.last_step.rounds_by_class()
    }

    /// Online cost of the most recent step.
    pub fn last_step_cost(&self) -> &CostLedger {
        &self.last_step
    }

    /// Setup + prefill + decode merged.
    pub fn total_cost(&self) -> CostLedger {
        merged_phases(&self.setup, &self.prefill, &self.decode)
    }
}
