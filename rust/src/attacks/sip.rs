//! SIP — learning-based inversion (Chen et al. 2024).
//!
//! The attacker trains an inversion model on its auxiliary corpus: features
//! are per-position slices of the target intermediate (computed with the
//! attacker's own query access), labels are the tokens. Our inversion model
//! is position-wise ridge regression onto one-hot token targets (the
//! paper's GRU, reduced to its linear core — sufficient to reach the
//! plaintext recovery rates the paper reports on templated data).

use crate::model::{ModelConfig, ModelWeights};
use crate::tensor::FloatTensor;
use crate::Result;

use super::linalg::Ridge;
use super::{featurize, plaintext_intermediate, TargetOp};

/// A trained SIP inversion model for one target op.
pub struct SipModel {
    op: TargetOp,
    ridge: Ridge,
    vocab: usize,
}

impl SipModel {
    /// Train on auxiliary sentences (attacker-side plaintext access).
    pub fn train(
        cfg: &ModelConfig,
        w: &ModelWeights,
        aux: &[Vec<u32>],
        op: TargetOp,
        lambda: f64,
    ) -> Result<SipModel> {
        let n = cfg.n_ctx;
        anyhow::ensure!(!aux.is_empty(), "empty aux corpus");
        let mut feats: Vec<FloatTensor> = Vec::with_capacity(aux.len());
        let mut labels: Vec<&[u32]> = Vec::with_capacity(aux.len());
        for sent in aux {
            let obs = plaintext_intermediate(cfg, w, sent, op);
            feats.push(featurize(op, &obs, n, cfg.h));
            labels.push(sent);
        }
        let fdim = feats[0].cols();
        let rows = aux.len() * n;
        let mut x = FloatTensor::zeros(rows, fdim);
        let mut y = FloatTensor::zeros(rows, cfg.vocab);
        for (i, (f, sent)) in feats.iter().zip(&labels).enumerate() {
            for r in 0..n {
                x.row_mut(i * n + r).copy_from_slice(f.row(r));
                y.set(i * n + r, sent[r] as usize, 1.0);
            }
        }
        let ridge = Ridge::fit(&x, &y, lambda).ok_or_else(|| anyhow::anyhow!("singular ridge system"))?;
        Ok(SipModel { op, ridge, vocab: cfg.vocab })
    }

    /// Reconstruct a token sequence from an observed intermediate.
    pub fn invert(&self, obs: &FloatTensor, n: usize, h: usize) -> Vec<u32> {
        let f = featurize(self.op, obs, n, h);
        let scores = self.ridge.predict(&f);
        (0..n)
            .map(|r| {
                let row = scores.row(r);
                (0..self.vocab)
                    .max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap())
                    .unwrap() as u32
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attacks::rouge::rouge_l_f1;
    use crate::attacks::{content_tokens, random_like};
    use crate::util::rng::Rng;

    /// End-to-end sanity: SIP recovers most of a plaintext O4 but nothing
    /// from a random observation.
    #[test]
    fn sip_separates_plaintext_from_random() {
        let mut cfg = ModelConfig::bert_tiny();
        cfg.layers = 1;
        cfg.n_ctx = 12;
        cfg.vocab = 64;
        let w = ModelWeights::random(&cfg, 111);
        let mut rng = Rng::new(112);
        let sent = |rng: &mut Rng| -> Vec<u32> {
            (0..cfg.n_ctx).map(|_| 4 + rng.below(cfg.vocab - 4) as u32).collect()
        };
        let aux: Vec<Vec<u32>> = (0..160).map(|_| sent(&mut rng)).collect();
        let model = SipModel::train(&cfg, &w, &aux, TargetOp::O5, 1e-3).unwrap();

        let victim = sent(&mut rng);
        let obs = plaintext_intermediate(&cfg, &w, &victim, TargetOp::O5);
        let rec = model.invert(&obs, cfg.n_ctx, cfg.h);
        let f1_plain = rouge_l_f1(&content_tokens(&victim), &content_tokens(&rec));

        let rand_obs = random_like(&obs, &mut rng);
        let rec_rand = model.invert(&rand_obs, cfg.n_ctx, cfg.h);
        let f1_rand = rouge_l_f1(&content_tokens(&victim), &content_tokens(&rec_rand));

        assert!(f1_plain > 60.0, "plaintext recovery too weak: {f1_plain}");
        assert!(f1_rand < f1_plain / 2.0, "random {f1_rand} vs plaintext {f1_plain}");
    }
}
