//! The Table 2/4 experiment runner: attacks × conditions × targets with
//! multi-seed aggregation, plus the Fig. 4/9 text-recovery examples.

use std::collections::BTreeMap;

use crate::engine::{CentaurEngine, EngineOptions};
use crate::model::{ModelConfig, ModelWeights};
use crate::net::NetworkProfile;
use crate::runtime::NativeBackend;
use crate::tensor::FloatTensor;
use crate::util::rng::Rng;
use crate::Result;

use super::bre::BreModel;
use super::eia::{eia_invert, EiaConfig};
use super::rouge::{mean_std, rouge_l_f1};
use super::sip::SipModel;
use super::{content_tokens, plaintext_intermediate, random_like, Condition, TargetOp};

/// Attack family.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum AttackKind {
    /// Learning-based inversion (ridge regression).
    Sip,
    /// Discrete-optimization inversion (greedy coordinate descent).
    Eia,
    /// Continuous-space inversion (prototype matching).
    Bre,
}

impl AttackKind {
    /// All attack families, in table order.
    pub const ALL: [AttackKind; 3] = [AttackKind::Sip, AttackKind::Eia, AttackKind::Bre];
    /// Table label.
    pub fn name(self) -> &'static str {
        match self {
            AttackKind::Sip => "SIP",
            AttackKind::Eia => "EIA",
            AttackKind::Bre => "BRE",
        }
    }
}

/// Experiment configuration.
pub struct AttackExperiment<'a> {
    /// Model under attack.
    pub cfg: &'a ModelConfig,
    /// Victim model parameters.
    pub weights: &'a ModelWeights,
    /// Auxiliary (attacker) corpus.
    pub aux: &'a [Vec<u32>],
    /// Private victim sentences.
    pub private: &'a [Vec<u32>],
    /// Independent repetitions (mean ± std).
    pub seeds: u64,
    /// Victim sentences used per seed (per paper: 4×20 batches; reduced
    /// here — configurable from the CLI).
    pub sentences: usize,
    /// EIA uses fewer sentences (it is the expensive attack).
    pub eia_sentences: usize,
    /// EIA search budget.
    pub eia: EiaConfig,
    /// Aux sentences used to train SIP/BRE.
    pub aux_train: usize,
    /// Target ops to attack (default: all four).
    pub ops: Vec<TargetOp>,
}

/// One table cell: ROUGE-L F1 mean ± std over seeds.
#[derive(Clone, Copy, Debug, Default)]
pub struct Cell {
    /// Mean ROUGE-L F1 over seeds.
    pub mean: f64,
    /// Standard deviation over seeds.
    pub std: f64,
}

/// Result keyed by (attack, condition, target).
pub type TableResult = BTreeMap<(AttackKind, usize, TargetOp), Cell>;

/// Collect the permuted observations Centaur's P1 actually sees for each
/// victim sentence (one engine per seed ⇒ fresh permutations).
fn permuted_observations(
    cfg: &ModelConfig,
    w: &ModelWeights,
    sentences: &[Vec<u32>],
    seed: u64,
) -> Result<BTreeMap<TargetOp, Vec<FloatTensor>>> {
    let mut engine = CentaurEngine::with_backend(
        cfg,
        w,
        Box::new(NativeBackend::new()),
        EngineOptions { profile: NetworkProfile::lan(), seed, record_views: true, fast_sim: true, ..Default::default() },
    )?;
    let mut out: BTreeMap<TargetOp, Vec<FloatTensor>> = BTreeMap::new();
    for sent in sentences {
        engine.infer(sent)?;
        for (op, label) in [
            (TargetOp::O1, "O1pi1 layer0"),
            (TargetOp::O4, "O4+X pi layer0"),
            (TargetOp::O5, "O5pi2 layer0"),
            (TargetOp::O6, "O6+L1 pi layer0"),
        ] {
            let rec = engine
                .views
                .find(label)
                .and_then(|r| r.tensor.clone())
                .ok_or_else(|| anyhow::anyhow!("missing view {label}"))?;
            out.entry(op).or_default().push(rec);
        }
    }
    Ok(out)
}

/// Run the full attack grid. Returns cells averaged over seeds.
pub fn run(exp: &AttackExperiment) -> Result<TableResult> {
    let mut acc: BTreeMap<(AttackKind, usize, TargetOp), Vec<f64>> = BTreeMap::new();
    for seed_i in 0..exp.seeds {
        let mut rng = Rng::new(0xA77AC4 ^ seed_i);
        let victims: Vec<Vec<u32>> =
            (0..exp.sentences).map(|i| exp.private[(seed_i as usize * exp.sentences + i) % exp.private.len()].clone()).collect();
        let aux: Vec<Vec<u32>> = exp.aux.iter().take(exp.aux_train).cloned().collect();
        let permuted = permuted_observations(exp.cfg, exp.weights, &victims, 0x5EED ^ seed_i)?;

        for &op in &exp.ops {
            // attacker-side models (trained once per op per seed)
            let sip = SipModel::train(exp.cfg, exp.weights, &aux, op, 1e-2)?;
            let bre = BreModel::train(exp.cfg, exp.weights, &aux, op);

            for cond in Condition::ALL {
                let mut scores: BTreeMap<AttackKind, Vec<f64>> = BTreeMap::new();
                for (vi, victim) in victims.iter().enumerate() {
                    let obs = match cond {
                        Condition::Plaintext => plaintext_intermediate(exp.cfg, exp.weights, victim, op),
                        Condition::Permuted => permuted[&op][vi].clone(),
                        Condition::Random => {
                            let plain = plaintext_intermediate(exp.cfg, exp.weights, victim, op);
                            random_like(&plain, &mut rng)
                        }
                    };
                    let truth = content_tokens(victim);
                    // SIP
                    let rec = sip.invert(&obs, exp.cfg.n_ctx, exp.cfg.h);
                    scores.entry(AttackKind::Sip).or_default().push(rouge_l_f1(&truth, &content_tokens(&rec)));
                    // BRE
                    let rec = bre.invert(&obs, exp.cfg.n_ctx, exp.cfg.h);
                    scores.entry(AttackKind::Bre).or_default().push(rouge_l_f1(&truth, &content_tokens(&rec)));
                    // EIA (subset of sentences)
                    if vi < exp.eia_sentences {
                        let rec = eia_invert(exp.cfg, exp.weights, &obs, op, &exp.eia, &mut rng);
                        scores.entry(AttackKind::Eia).or_default().push(rouge_l_f1(&truth, &content_tokens(&rec)));
                    }
                }
                for (attack, vals) in scores {
                    let (m, _) = mean_std(&vals);
                    acc.entry((attack, cond as usize, op)).or_default().push(m);
                }
            }
        }
    }
    Ok(acc
        .into_iter()
        .map(|(k, seeds)| {
            let (mean, std) = mean_std(&seeds);
            (k, Cell { mean, std })
        })
        .collect())
}

/// A Fig. 4/9-style example: (ground truth text, SIP recovery from
/// plaintext O1, SIP recovery from permuted O1).
pub fn recovery_example(
    cfg: &ModelConfig,
    w: &ModelWeights,
    aux: &[Vec<u32>],
    victim: &[u32],
    vocab: &crate::data::Vocab,
    seed: u64,
) -> Result<(String, String, String)> {
    let sip = SipModel::train(cfg, w, aux, TargetOp::O1, 1e-2)?;
    let plain_obs = plaintext_intermediate(cfg, w, victim, TargetOp::O1);
    let rec_plain = sip.invert(&plain_obs, cfg.n_ctx, cfg.h);
    let permuted = permuted_observations(cfg, w, std::slice::from_ref(&victim.to_vec()), seed)?;
    let rec_perm = sip.invert(&permuted[&TargetOp::O1][0], cfg.n_ctx, cfg.h);
    Ok((vocab.decode(victim), vocab.decode(&rec_plain), vocab.decode(&rec_perm)))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mini end-to-end grid: plaintext SIP ≫ permuted SIP ≈ random SIP.
    #[test]
    fn grid_shows_permutation_defense() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 141);
        let mut rng = Rng::new(142);
        let sent = |rng: &mut Rng| -> Vec<u32> {
            let mut s: Vec<u32> = vec![1];
            s.extend((0..20).map(|_| 4 + rng.below(cfg.vocab - 4) as u32));
            s.push(2);
            s.resize(cfg.n_ctx, 0);
            s
        };
        let aux: Vec<Vec<u32>> = (0..100).map(|_| sent(&mut rng)).collect();
        let private: Vec<Vec<u32>> = (0..6).map(|_| sent(&mut rng)).collect();
        let exp = AttackExperiment {
            cfg: &cfg,
            weights: &w,
            aux: &aux,
            private: &private,
            seeds: 1,
            sentences: 4,
            eia_sentences: 0, // EIA covered by its own test
            eia: EiaConfig { candidates: 4, sweeps: 1 },
            aux_train: 100,
            ops: vec![TargetOp::O5],
        };
        let table = run(&exp).unwrap();
        let cell = |a: AttackKind, c: Condition, o: TargetOp| table[&(a, c as usize, o)].mean;
        let plain = cell(AttackKind::Sip, Condition::Plaintext, TargetOp::O5);
        let perm = cell(AttackKind::Sip, Condition::Permuted, TargetOp::O5);
        let rand = cell(AttackKind::Sip, Condition::Random, TargetOp::O5);
        assert!(plain > 35.0, "plaintext SIP too weak: {plain}");
        assert!(perm < plain / 2.0, "permuted {perm} vs plaintext {plain}");
        assert!((perm - rand).abs() < 25.0, "permuted {perm} should be near random {rand}");
    }
}
