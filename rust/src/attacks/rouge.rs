//! ROUGE-L F1 over token sequences — the attack-quality metric of the
//! paper's Tables 2/4 (longest common subsequence, order-sensitive).

/// Length of the longest common subsequence.
pub fn lcs_len(a: &[u32], b: &[u32]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    // rolling 1-D DP
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for &x in a {
        for (j, &y) in b.iter().enumerate() {
            cur[j + 1] = if x == y { prev[j] + 1 } else { prev[j + 1].max(cur[j]) };
        }
        std::mem::swap(&mut prev, &mut cur);
        cur[0] = 0;
    }
    prev[b.len()]
}

/// ROUGE-L F1 in percent (0-100) between a reference and a candidate.
pub fn rouge_l_f1(reference: &[u32], candidate: &[u32]) -> f64 {
    if reference.is_empty() || candidate.is_empty() {
        return 0.0;
    }
    let l = lcs_len(reference, candidate) as f64;
    if l == 0.0 {
        return 0.0;
    }
    let p = l / candidate.len() as f64;
    let r = l / reference.len() as f64;
    100.0 * 2.0 * p * r / (p + r)
}

/// Mean and sample standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let m = xs.iter().sum::<f64>() / xs.len() as f64;
    if xs.len() < 2 {
        return (m, 0.0);
    }
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    (m, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_sequences_score_100() {
        let s = vec![4, 5, 6, 7];
        assert!((rouge_l_f1(&s, &s) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn disjoint_sequences_score_0() {
        assert_eq!(rouge_l_f1(&[1, 2, 3], &[4, 5, 6]), 0.0);
    }

    #[test]
    fn partial_overlap() {
        // LCS([a b c d], [a x c y]) = [a c] → P=R=0.5 → F1=50
        let f1 = rouge_l_f1(&[1, 2, 3, 4], &[1, 9, 3, 8]);
        assert!((f1 - 50.0).abs() < 1e-9);
    }

    #[test]
    fn order_sensitivity() {
        // same bag of tokens, reversed order → LCS 1
        let f1 = rouge_l_f1(&[1, 2, 3, 4], &[4, 3, 2, 1]);
        assert!(f1 < 30.0);
    }

    #[test]
    fn lcs_dp_correct() {
        assert_eq!(lcs_len(&[1, 3, 5, 7], &[1, 5, 7, 9]), 3);
        assert_eq!(lcs_len(&[], &[1]), 0);
    }

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
    }
}
