//! Small dense linear algebra for the attack models (ridge regression via
//! Gaussian elimination — feature dims here are ≤ a few hundred).

use crate::tensor::FloatTensor;

/// Solve `A x = b` for square `A` (in f64, partial pivoting). Returns None
/// if singular.
pub fn solve(a: &[Vec<f64>], b: &[f64]) -> Option<Vec<f64>> {
    let n = a.len();
    let mut m: Vec<Vec<f64>> = a.iter().cloned().collect();
    let mut rhs = b.to_vec();
    for col in 0..n {
        // pivot
        let piv = (col..n).max_by(|&i, &j| m[i][col].abs().partial_cmp(&m[j][col].abs()).unwrap())?;
        if m[piv][col].abs() < 1e-12 {
            return None;
        }
        m.swap(col, piv);
        rhs.swap(col, piv);
        let d = m[col][col];
        for r in (col + 1)..n {
            let f = m[r][col] / d;
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                m[r][c] -= f * m[col][c];
            }
            rhs[r] -= f * rhs[col];
        }
    }
    let mut x = vec![0.0; n];
    for r in (0..n).rev() {
        let mut acc = rhs[r];
        for c in (r + 1)..n {
            acc -= m[r][c] * x[c];
        }
        x[r] = acc / m[r][r];
    }
    Some(x)
}

/// Ridge regression fit: given features `X (n×d)` and multi-output targets
/// `Y (n×k)`, return `W (d×k)` minimizing `‖XW − Y‖² + λ‖W‖²`.
pub struct Ridge {
    /// (d×k) weights.
    pub w: FloatTensor,
}

impl Ridge {
    /// Solve the regularized normal equations (`None` if singular).
    pub fn fit(x: &FloatTensor, y: &FloatTensor, lambda: f64) -> Option<Ridge> {
        let (n, d) = x.shape();
        let (n2, k) = y.shape();
        assert_eq!(n, n2);
        // XtX (d×d) in f64
        let mut xtx = vec![vec![0.0f64; d]; d];
        for r in 0..n {
            let row = x.row(r);
            for i in 0..d {
                let xi = row[i] as f64;
                if xi == 0.0 {
                    continue;
                }
                for j in i..d {
                    xtx[i][j] += xi * row[j] as f64;
                }
            }
        }
        for i in 0..d {
            for j in 0..i {
                xtx[i][j] = xtx[j][i];
            }
            xtx[i][i] += lambda;
        }
        // XtY (d×k)
        let mut xty = vec![vec![0.0f64; k]; d];
        for r in 0..n {
            let xr = x.row(r);
            let yr = y.row(r);
            for i in 0..d {
                let xi = xr[i] as f64;
                if xi == 0.0 {
                    continue;
                }
                for c in 0..k {
                    xty[i][c] += xi * yr[c] as f64;
                }
            }
        }
        // Solve per output column (reuse factorization would be nicer; the
        // attack dims make plain resolves acceptable).
        // Factor once via inverse-free approach: solve for each column.
        let mut w = FloatTensor::zeros(d, k);
        for c in 0..k {
            let bcol: Vec<f64> = (0..d).map(|i| xty[i][c]).collect();
            let sol = solve(&xtx, &bcol)?;
            for i in 0..d {
                w.set(i, c, sol[i] as f32);
            }
        }
        Some(Ridge { w })
    }

    /// Predict `(n×k)` outputs for features `(n×d)`.
    pub fn predict(&self, x: &FloatTensor) -> FloatTensor {
        x.matmul(&self.w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_small_system() {
        let a = vec![vec![2.0, 1.0], vec![1.0, 3.0]];
        let x = solve(&a, &[5.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-9);
        assert!((x[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn singular_returns_none() {
        let a = vec![vec![1.0, 2.0], vec![2.0, 4.0]];
        assert!(solve(&a, &[1.0, 2.0]).is_none());
    }

    #[test]
    fn ridge_recovers_linear_map() {
        // y = X W* exactly; ridge with tiny λ should recover W*.
        let mut rng = crate::util::rng::Rng::new(7);
        let (n, d, k) = (200, 8, 3);
        let x = FloatTensor::from_vec(n, d, rng.vec_gaussian_f32(n * d, 1.0));
        let wstar = FloatTensor::from_vec(d, k, rng.vec_gaussian_f32(d * k, 1.0));
        let y = x.matmul(&wstar);
        let model = Ridge::fit(&x, &y, 1e-6).unwrap();
        assert!(model.w.max_abs_diff(&wstar) < 1e-2);
        let pred = model.predict(&x);
        assert!(pred.max_abs_diff(&y) < 1e-2);
    }
}
