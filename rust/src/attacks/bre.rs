//! BRE — continuous-space inversion (Chen et al. 2024 flavor).
//!
//! The attacker builds per-token *prototypes* in the intermediate feature
//! space from its auxiliary corpus (mean feature vector over occurrences),
//! then decodes each observed position to the nearest prototype by cosine
//! similarity — embedding-space inversion without the discrete search.

use std::collections::BTreeMap;

use crate::model::{ModelConfig, ModelWeights};
use crate::tensor::FloatTensor;

use super::{featurize, plaintext_intermediate, TargetOp};

/// Prototype table for one target op.
pub struct BreModel {
    op: TargetOp,
    /// token id → mean feature vector.
    protos: BTreeMap<u32, Vec<f32>>,
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

impl BreModel {
    /// Build prototypes from the auxiliary corpus.
    pub fn train(cfg: &ModelConfig, w: &ModelWeights, aux: &[Vec<u32>], op: TargetOp) -> BreModel {
        let n = cfg.n_ctx;
        let mut sums: BTreeMap<u32, (Vec<f64>, usize)> = BTreeMap::new();
        for sent in aux {
            let obs = plaintext_intermediate(cfg, w, sent, op);
            let f = featurize(op, &obs, n, cfg.h);
            for r in 0..n {
                let entry = sums.entry(sent[r]).or_insert_with(|| (vec![0.0; f.cols()], 0));
                for (acc, &v) in entry.0.iter_mut().zip(f.row(r)) {
                    *acc += v as f64;
                }
                entry.1 += 1;
            }
        }
        let protos = sums
            .into_iter()
            .map(|(tok, (sum, cnt))| (tok, sum.iter().map(|&s| (s / cnt as f64) as f32).collect()))
            .collect();
        BreModel { op, protos }
    }

    /// Decode an observation to tokens via nearest prototype.
    pub fn invert(&self, obs: &FloatTensor, n: usize, h: usize) -> Vec<u32> {
        let f = featurize(self.op, obs, n, h);
        (0..n)
            .map(|r| {
                self.protos
                    .iter()
                    .max_by(|(_, a), (_, b)| {
                        cosine(f.row(r), a).partial_cmp(&cosine(f.row(r), b)).unwrap()
                    })
                    .map(|(&tok, _)| tok)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attacks::rouge::rouge_l_f1;
    use crate::attacks::{content_tokens, random_like};
    use crate::util::rng::Rng;

    #[test]
    fn bre_prototype_separation() {
        let mut cfg = ModelConfig::bert_tiny();
        cfg.layers = 1;
        cfg.n_ctx = 10;
        cfg.vocab = 48;
        let w = ModelWeights::random(&cfg, 131);
        let mut rng = Rng::new(132);
        let sent = |rng: &mut Rng| -> Vec<u32> {
            (0..cfg.n_ctx).map(|_| 4 + rng.below(cfg.vocab - 4) as u32).collect()
        };
        let aux: Vec<Vec<u32>> = (0..120).map(|_| sent(&mut rng)).collect();
        let model = BreModel::train(&cfg, &w, &aux, TargetOp::O6);

        let victim = sent(&mut rng);
        let obs = plaintext_intermediate(&cfg, &w, &victim, TargetOp::O6);
        let rec = model.invert(&obs, cfg.n_ctx, cfg.h);
        let f1_plain = rouge_l_f1(&content_tokens(&victim), &content_tokens(&rec));
        let rec_r = model.invert(&random_like(&obs, &mut rng), cfg.n_ctx, cfg.h);
        let f1_rand = rouge_l_f1(&content_tokens(&victim), &content_tokens(&rec_r));
        assert!(f1_plain > f1_rand, "plaintext {f1_plain} !> random {f1_rand}");
        assert!(f1_plain > 30.0, "prototype recovery too weak: {f1_plain}");
    }
}
