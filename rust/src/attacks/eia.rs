//! EIA — discrete optimization attack (Song & Raghunathan 2020 flavor).
//!
//! Greedy coordinate descent over the vocabulary: starting from a random
//! sentence, repeatedly re-pick each position's token to minimize the
//! distance between the forward-computed target intermediate and the
//! observation (the paper's Gumbel-softmax relaxation, discretized; the
//! candidate set is subsampled for tractability on this 1-core testbed —
//! DESIGN.md documents the reduction).

use crate::model::{ModelConfig, ModelWeights};
use crate::tensor::FloatTensor;
use crate::util::rng::Rng;

use super::{featurize, plaintext_intermediate, TargetOp};

/// EIA configuration.
pub struct EiaConfig {
    /// Candidate tokens sampled per position per sweep.
    pub candidates: usize,
    /// Full sweeps over the sequence.
    pub sweeps: usize,
}

impl Default for EiaConfig {
    fn default() -> Self {
        EiaConfig { candidates: 32, sweeps: 1 }
    }
}

fn distance(a: &FloatTensor, b: &FloatTensor) -> f64 {
    debug_assert_eq!(a.shape(), b.shape());
    a.data()
        .iter()
        .zip(b.data())
        .map(|(&x, &y)| ((x - y) as f64) * ((x - y) as f64))
        .sum()
}

/// Run EIA against one observed intermediate; returns the recovered tokens.
pub fn eia_invert(
    cfg: &ModelConfig,
    w: &ModelWeights,
    obs: &FloatTensor,
    op: TargetOp,
    econf: &EiaConfig,
    rng: &mut Rng,
) -> Vec<u32> {
    let n = cfg.n_ctx;
    let obs_f = featurize(op, obs, n, cfg.h);
    // random init over content tokens
    let mut cur: Vec<u32> = (0..n).map(|_| 4 + rng.below(cfg.vocab - 4) as u32).collect();
    let eval = |tokens: &[u32]| -> f64 {
        let im = plaintext_intermediate(cfg, w, tokens, op);
        distance(&featurize(op, &im, n, cfg.h), &obs_f)
    };
    let mut best = eval(&cur);
    for _ in 0..econf.sweeps {
        for pos in 0..n {
            let original = cur[pos];
            let mut best_tok = original;
            for _ in 0..econf.candidates {
                let cand = rng.below(cfg.vocab) as u32;
                if cand == best_tok {
                    continue;
                }
                cur[pos] = cand;
                let d = eval(&cur);
                if d < best {
                    best = d;
                    best_tok = cand;
                }
            }
            cur[pos] = best_tok;
        }
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attacks::rouge::rouge_l_f1;
    use crate::attacks::{content_tokens, random_like};

    #[test]
    fn eia_recovers_more_from_plaintext_than_random() {
        let mut cfg = ModelConfig::bert_tiny();
        cfg.layers = 1;
        cfg.n_ctx = 8;
        cfg.vocab = 32;
        let w = ModelWeights::random(&cfg, 121);
        let mut rng = Rng::new(122);
        let victim: Vec<u32> = (0..cfg.n_ctx).map(|_| 4 + rng.below(cfg.vocab - 4) as u32).collect();
        let obs = plaintext_intermediate(&cfg, &w, &victim, TargetOp::O1);
        let econf = EiaConfig { candidates: cfg.vocab, sweeps: 2 };
        let rec = eia_invert(&cfg, &w, &obs, TargetOp::O1, &econf, &mut rng);
        let f1_plain = rouge_l_f1(&content_tokens(&victim), &content_tokens(&rec));

        let rand_obs = random_like(&obs, &mut rng);
        let rec_r = eia_invert(&cfg, &w, &rand_obs, TargetOp::O1, &econf, &mut rng);
        let f1_rand = rouge_l_f1(&content_tokens(&victim), &content_tokens(&rec_r));
        assert!(
            f1_plain > f1_rand + 20.0,
            "plaintext {f1_plain} vs random {f1_rand} — EIA should separate"
        );
    }
}
