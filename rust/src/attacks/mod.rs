//! Data Reconstruction Attack (DRA) harness — the paper's §7.2 experiments
//! (Tables 2/4, Figs. 4/9).
//!
//! Threat model (paper's, deliberately idealized): the adversary has
//! unrestricted query access to the model's intermediate components, an
//! out-of-distribution auxiliary corpus, and observes **one** intermediate
//! tensor per victim sentence. Three attack families:
//!
//! * [`sip`] — learning-based (SIP, Chen et al. 2024): an inversion model
//!   (ridge regression per position → token distribution, standing in for
//!   the paper's GRU) trained on auxiliary data.
//! * [`eia`] — discrete optimization (EIA, Song & Raghunathan 2020): greedy
//!   coordinate descent over the vocabulary matching the observed
//!   intermediate (standing in for Gumbel-softmax relaxation).
//! * [`bre`] — continuous-space inversion (BRE, Chen et al. 2024):
//!   prototype matching in the intermediate feature space.
//!
//! Conditions per target (`O1, O4, O5, O6`): **W/O** — plaintext
//! intermediates (what permutation-only PPTI exposes); **W** — what
//! Centaur's P1 actually reconstructs (the permuted tensors recorded by
//! [`crate::engine::views::Views`]); **Rand** — random tensors
//! (the floor). DESIGN.md documents the simplifications vs the original
//! attack implementations.

pub mod bre;
pub mod eia;
pub mod harness;
pub mod linalg;
pub mod rouge;
pub mod sip;

use crate::model::{forward_trace, ModelConfig, ModelWeights, Variant};
use crate::tensor::FloatTensor;
use crate::util::rng::Rng;

/// Intermediate tensor under attack (paper's Table 2 columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TargetOp {
    /// `QKᵀ/√dh` attention scores, heads stacked `(h·n, n)`.
    O1,
    /// Attention output after `W_O`: `(n, d)`.
    O4,
    /// FFN up-projection (pre-GeLU): `(n, k)`.
    O5,
    /// FFN down-projection: `(n, d)`.
    O6,
}

impl TargetOp {
    /// All attack targets, in table order.
    pub const ALL: [TargetOp; 4] = [TargetOp::O1, TargetOp::O4, TargetOp::O5, TargetOp::O6];
    /// Table label.
    pub fn name(self) -> &'static str {
        match self {
            TargetOp::O1 => "O1",
            TargetOp::O4 => "O4",
            TargetOp::O5 => "O5",
            TargetOp::O6 => "O6",
        }
    }
}

/// Observation condition (paper's Table 2 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Condition {
    /// "W/O": plaintext intermediate (permutation-only exposure).
    Plaintext,
    /// "W": the permuted tensor Centaur's P1 reconstructs.
    Permuted,
    /// "Rand": random tensor of the same shape/scale (attack floor).
    Random,
}

impl Condition {
    /// All observation conditions, in table order.
    pub const ALL: [Condition; 3] = [Condition::Plaintext, Condition::Permuted, Condition::Random];
    /// Table label.
    pub fn name(self) -> &'static str {
        match self {
            Condition::Plaintext => "W/O",
            Condition::Permuted => "W(Ours)",
            Condition::Random => "Rand",
        }
    }
}

/// Per-position feature matrix `(n, feat)` extracted from an observed
/// intermediate. For `O1` (heads stacked `(h·n, n)`) position `r` gets the
/// concatenation across heads of both its **row** (how r attends — query
/// side) and its **column** (how r is attended to — key side; this carries
/// most of the token identity).
pub fn featurize(op: TargetOp, obs: &FloatTensor, n: usize, h: usize) -> FloatTensor {
    match op {
        TargetOp::O1 => {
            let w = obs.cols();
            let feat = 2 * h * w;
            // clamp causal-mask sentinels (−1e5 / −1e9) so they don't
            // dominate the regression features
            let clamp = |v: f32| if v < -1e4 { 0.0 } else { v };
            FloatTensor::from_fn(n, feat, |r, c| {
                let head = (c / w) % h;
                let idx = c % w;
                clamp(if c < h * w {
                    obs.get(head * n + r, idx) // query-side row
                } else {
                    obs.get(head * n + idx, r.min(w - 1)) // key-side column
                })
            })
        }
        _ => obs.clone(),
    }
}

/// Plaintext layer-0 intermediate (the attacker's own forward pass; also
/// the "W/O" observation).
pub fn plaintext_intermediate(
    cfg: &ModelConfig,
    w: &ModelWeights,
    tokens: &[u32],
    op: TargetOp,
) -> FloatTensor {
    let t = forward_trace(cfg, w, tokens, Variant::Exact);
    let l = &t.layers[0];
    match op {
        TargetOp::O1 => l.o1.clone(),
        TargetOp::O4 => l.o4.clone(),
        TargetOp::O5 => l.o5.clone(),
        TargetOp::O6 => l.o6.clone(),
    }
}

/// Random observation with moments matched to a reference tensor.
pub fn random_like(reference: &FloatTensor, rng: &mut Rng) -> FloatTensor {
    let n = reference.len() as f32;
    let mean = reference.data().iter().sum::<f32>() / n;
    let var = reference.data().iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let std = var.sqrt().max(1e-6);
    FloatTensor::from_vec(
        reference.rows(),
        reference.cols(),
        (0..reference.len()).map(|_| mean + rng.next_gaussian() as f32 * std).collect(),
    )
}

/// Strip special tokens (PAD/CLS/SEP/UNK < 4) for ROUGE scoring.
pub fn content_tokens(tokens: &[u32]) -> Vec<u32> {
    tokens.iter().copied().filter(|&t| t > 3).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn featurize_o1_concats_rows_then_cols() {
        let (h, n) = (2, 3);
        let obs = FloatTensor::from_fn(h * n, n, |r, c| (r * 10 + c) as f32);
        let f = featurize(TargetOp::O1, &obs, n, h);
        assert_eq!(f.shape(), (n, 2 * h * n));
        // position 1, first half: head0 row 1 then head1 row (n+1)
        assert_eq!(f.get(1, 0), obs.get(1, 0));
        assert_eq!(f.get(1, n), obs.get(n + 1, 0));
        // position 1, second half: head0 column 1 entries
        assert_eq!(f.get(1, 2 * n), obs.get(0, 1));
        assert_eq!(f.get(1, 2 * n + 1), obs.get(1, 1));
    }

    #[test]
    fn featurize_o1_clamps_mask_sentinels() {
        let (h, n) = (1, 2);
        let obs = FloatTensor::from_vec(2, 2, vec![1.0, -1e9, 2.0, 3.0]);
        let f = featurize(TargetOp::O1, &obs, n, h);
        assert!(f.data().iter().all(|&v| v > -1e4));
    }

    #[test]
    fn random_like_matches_moments() {
        let mut rng = Rng::new(3);
        let t = FloatTensor::from_fn(40, 40, |r, c| ((r * 40 + c) as f32 * 0.173).sin() * 2.0 + 0.5);
        let r = random_like(&t, &mut rng);
        let mean = |x: &FloatTensor| x.data().iter().sum::<f32>() / x.len() as f32;
        assert!((mean(&r) - mean(&t)).abs() < 0.1);
    }

    #[test]
    fn content_tokens_strips_specials() {
        assert_eq!(content_tokens(&[1, 5, 6, 2, 0, 0]), vec![5, 6]);
    }
}
