//! Round-budget regression harness (DESIGN.md §Batched openings): pins the
//! exact per-`OpClass` rounds/token of a warm decode step against a golden
//! table, the way the byte floors are pinned in `engine` tests and
//! `bench_e2e` — any silent round growth (a protocol edit that adds an
//! opening flight, a batch that stops coalescing) fails here first.
//!
//! Round counts are deterministic and network-independent, so the golden
//! table must hold bit-exactly under every [`NetworkProfile`], in both KV
//! modes (plain per-step and fixed-operand correlated), and in fast-sim.

use centaur::engine::decoder::DecoderSession;
use centaur::engine::{CentaurEngine, EngineOptions};
use centaur::model::{ModelConfig, ModelWeights};
use centaur::net::{NetworkProfile, OpClass};
use centaur::runtime::NativeBackend;

/// Golden per-class rounds of one warm decode step on `gpt2-tiny`
/// (2 layers) under the **batched** schedule, in `OpClass::ALL` order:
///
/// * Linear 3/layer — append+scores flush, Π_PPP, value+residual flush
/// * Softmax 2/layer — Π_PPSM input flight + reshare flight
/// * LayerNorm 1/layer — the coalesced LN1/GeLU/LN2(/final-LN) reshares
/// * GeLU 0 — its conversions ride the LayerNorm flush / deferred sends
/// * Embedding 3 — client input share + the embedding Π_PPLN
/// * Adaptation 1 — logits return (final LN fused into the last layer)
const GOLDEN_BATCHED: [(OpClass, u64); 8] = [
    (OpClass::Linear, 6),
    (OpClass::Softmax, 4),
    (OpClass::Gelu, 0),
    (OpClass::LayerNorm, 2),
    (OpClass::Embedding, 3),
    (OpClass::Adaptation, 1),
    (OpClass::Correlation, 0),
    (OpClass::Other, 0),
];

/// Golden per-class rounds of the same step under the **sequential**
/// schedule (the PR 2/3 baseline): 12/layer + embedding 3 + adaptation 3.
const GOLDEN_SEQUENTIAL: [(OpClass, u64); 8] = [
    (OpClass::Linear, 8),
    (OpClass::Softmax, 4),
    (OpClass::Gelu, 4),
    (OpClass::LayerNorm, 8),
    (OpClass::Embedding, 3),
    (OpClass::Adaptation, 3),
    (OpClass::Correlation, 0),
    (OpClass::Other, 0),
];

fn golden_total(table: &[(OpClass, u64); 8]) -> u64 {
    table.iter().map(|&(_, r)| r).sum()
}

/// One warm decode step; returns `(rounds_by_class, bytes_by_class)` of
/// that step's ledger.
fn warm_step(
    profile: NetworkProfile,
    round_batching: bool,
    decode_correlations: bool,
    fast_sim: bool,
) -> ([(OpClass, u64); 8], [(OpClass, u64); 8]) {
    let cfg = ModelConfig::gpt2_tiny();
    let w = ModelWeights::random(&cfg, 0x20B);
    let mut eng = CentaurEngine::with_backend(
        &cfg,
        &w,
        Box::new(NativeBackend::new()),
        EngineOptions {
            profile,
            seed: 0x20C,
            round_batching,
            decode_correlations,
            fast_sim,
            ..Default::default()
        },
    )
    .unwrap();
    let mut sess = DecoderSession::new(&mut eng, &[7, 11, 13]).unwrap();
    sess.absorb(17).unwrap();
    assert_eq!(sess.decode_steps(), 1);
    let step = sess.last_step_cost().clone();
    (step.rounds_by_class(), step.bytes_by_class())
}

/// The tentpole pin: exact rounds/token per `OpClass` under every network
/// profile, in both KV modes — any deviation from the golden table is a
/// regression (or an improvement that must update the table *and*
/// EXPERIMENTS.md §Rounds).
#[test]
fn warm_step_rounds_pinned_per_profile_and_mode() {
    for name in NetworkProfile::ALL_NAMES {
        let profile = NetworkProfile::by_name(name).unwrap();
        for correlations in [true, false] {
            let (rounds, _) = warm_step(profile, true, correlations, false);
            assert_eq!(
                rounds, GOLDEN_BATCHED,
                "batched rounds/token drifted ({name}, correlations={correlations})"
            );
        }
        let (seq_rounds, _) = warm_step(profile, false, true, false);
        assert_eq!(seq_rounds, GOLDEN_SEQUENTIAL, "sequential rounds/token drifted ({name})");
    }
    assert_eq!(golden_total(&GOLDEN_BATCHED), 16);
    assert_eq!(golden_total(&GOLDEN_SEQUENTIAL), 30);
}

/// Fast-sim charges the same round schedule (charged-ideal twins batch
/// through the same `NetSim` deferral), so the golden table is
/// mode-independent.
#[test]
fn fast_sim_matches_the_golden_round_table() {
    let (rounds, bytes) = warm_step(NetworkProfile::lan(), true, true, true);
    let (_, full_bytes) = warm_step(NetworkProfile::lan(), true, true, false);
    assert_eq!(rounds, GOLDEN_BATCHED, "fast-sim rounds/token drifted");
    assert_eq!(bytes, full_bytes, "fast-sim bytes/token drifted from full mode");
}

/// The acceptance criterion: ≥40% fewer warm-step rounds than the
/// sequential baseline, with per-class bytes unchanged **exactly** (the
/// ≤1% tolerance of the criterion is met with zero slack — batching may
/// merge rounds, never move a byte).
#[test]
fn batching_cuts_rounds_40pct_with_identical_bytes() {
    let (bat_rounds, bat_bytes) = warm_step(NetworkProfile::wan2(), true, true, false);
    let (seq_rounds, seq_bytes) = warm_step(NetworkProfile::wan2(), false, true, false);
    let bat: u64 = bat_rounds.iter().map(|&(_, r)| r).sum();
    let seq: u64 = seq_rounds.iter().map(|&(_, r)| r).sum();
    assert!(
        bat * 10 <= seq * 6,
        "batched schedule must cut rounds/token >=40%: {bat} vs {seq}"
    );
    assert_eq!(bat_bytes, seq_bytes, "round batching must not change per-class bytes");
}

/// ISSUE 7 golden row: a speculative verify step rides ONE batched flight
/// chain — exactly the [`GOLDEN_BATCHED`] per-class round table — no
/// matter how many verify lanes it carries. k scales bytes (each lane
/// ships its own payloads), never rounds, which is the whole speculative
/// win: rounds per *accepted* token amortize to `16 / accepted-per-step`.
#[test]
fn speculative_verify_step_charges_one_flight_chain_regardless_of_k() {
    use centaur::engine::draft::Draft;

    let cfg = ModelConfig::gpt2_tiny();
    let w = ModelWeights::random(&cfg, 0x20F);
    for k in [1usize, 2, 4, 8] {
        // Adversarial draft: every verify step keeps exactly one token, so
        // the per-step ledger is fully deterministic in k.
        let mut eng = CentaurEngine::with_backend(
            &cfg,
            &w,
            Box::new(NativeBackend::new()),
            EngineOptions { seed: 0x210, ..Default::default() },
        )
        .unwrap();
        let mut sess = DecoderSession::new(&mut eng, &[7, 11, 13]).unwrap();
        let emitted = sess.step_speculative(&Draft::Adversarial, k).unwrap();
        assert_eq!(emitted.len(), 1, "the adversarial draft degenerates to one token per step");
        assert_eq!(
            sess.decode_cost().rounds_by_class(),
            GOLDEN_BATCHED,
            "k={k}: a verify step must charge exactly one batched flight chain"
        );
        // A second verify step doubles the budget — still k-independent.
        sess.step_speculative(&Draft::Adversarial, k).unwrap();
        assert_eq!(sess.decode_cost().rounds_total(), 2 * golden_total(&GOLDEN_BATCHED), "k={k}");
    }

    // With a real draft the chain is still one golden row, and the
    // amortized metric divides it by whatever the step accepted.
    let mut eng = CentaurEngine::with_backend(
        &cfg,
        &w,
        Box::new(NativeBackend::new()),
        EngineOptions { seed: 0x210, ..Default::default() },
    )
    .unwrap();
    let mut sess = DecoderSession::new(&mut eng, &[7, 11, 13]).unwrap();
    let emitted = sess.step_speculative(&Draft::tiny(&cfg, &w), 4).unwrap();
    assert!(!emitted.is_empty());
    assert_eq!(sess.decode_cost().rounds_by_class(), GOLDEN_BATCHED);
    let amortized = sess.decode_rounds_per_accepted_token();
    let want = golden_total(&GOLDEN_BATCHED) as f64 / emitted.len() as f64;
    assert!((amortized - want).abs() < 1e-12, "rounds/accepted {amortized} != 16/{}", emitted.len());
}

/// Per-step rounds are position-independent: prefill absorbs and warm
/// steps share one budget, so a single pinned step is representative.
#[test]
fn step_rounds_are_position_independent() {
    let cfg = ModelConfig::gpt2_tiny();
    let w = ModelWeights::random(&cfg, 0x20D);
    let mut eng = CentaurEngine::with_backend(
        &cfg,
        &w,
        Box::new(NativeBackend::new()),
        EngineOptions { seed: 0x20E, ..Default::default() },
    )
    .unwrap();
    let mut sess = DecoderSession::new(&mut eng, &[5, 9]).unwrap();
    let mut seen = Vec::new();
    for t in [21u32, 34, 55] {
        sess.absorb(t).unwrap();
        seen.push(sess.last_step_cost().rounds_total());
    }
    assert!(seen.windows(2).all(|w| w[0] == w[1]), "per-step rounds drifted: {seen:?}");
    assert_eq!(sess.decode_rounds_per_token(), golden_total(&GOLDEN_BATCHED));
    assert_eq!(
        sess.last_step_rounds_by_class(),
        GOLDEN_BATCHED,
        "session accessor must expose the pinned breakdown"
    );
}
