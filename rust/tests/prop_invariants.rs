//! Property-based invariants across module boundaries (the crate's
//! substitute for proptest; see rust/src/util/prop.rs).

use centaur::engine::views::Views;
use centaur::fixed;
use centaur::mpc::{nonlin as smpc, Mpc};
use centaur::net::{NetSim, NetworkProfile, OpClass};
use centaur::perm::Perm;
use centaur::protocols::{nonlin, ppp};
use centaur::ring;
use centaur::runtime::NativeBackend;
use centaur::tensor::{FloatTensor, RingTensor};
use centaur::util::prop::check;

fn mk() -> Mpc {
    Mpc::new(NetSim::new(NetworkProfile::lan()), 0xBEEF)
}

#[test]
fn prop_share_algebra_is_ring_homomorphic() {
    check("share homomorphism", 60, |g| {
        let mut mpc = mk();
        let n = g.dim(24);
        let x = RingTensor::from_vec(1, n, g.vec_i64(n));
        let y = RingTensor::from_vec(1, n, g.vec_i64(n));
        let sx = mpc.share_local(&x);
        let sy = mpc.share_local(&y);
        assert_eq!(mpc.add(&sx, &sy).reconstruct(), ring::add(&x, &y));
        assert_eq!(mpc.sub(&sx, &sy).reconstruct(), ring::sub(&x, &y));
        let p = RingTensor::from_vec(1, n, g.vec_i64(n));
        assert_eq!(mpc.add_plain(&sx, &p).reconstruct(), ring::add(&x, &p));
    });
}

#[test]
fn prop_beaver_matmul_correct_for_any_shape() {
    check("beaver matmul", 15, |g| {
        let mut mpc = mk();
        let (m, k, n) = (g.dim(6), g.dim(8), g.dim(6));
        let a = FloatTensor::from_vec(m, k, g.vec_small_f64(m * k).iter().map(|&v| v as f32 * 0.1).collect());
        let b = FloatTensor::from_vec(k, n, g.vec_small_f64(k * n).iter().map(|&v| v as f32 * 0.1).collect());
        let sa = mpc.share_local(&fixed::encode_tensor(&a));
        let sb = mpc.share_local(&fixed::encode_tensor(&b));
        let got = fixed::decode_tensor(&mpc.matmul(&sa, &sb, OpClass::Linear).reconstruct());
        let want = a.matmul(&b);
        assert!(got.max_abs_diff(&want) < 0.02, "diff {}", got.max_abs_diff(&want));
    });
}

#[test]
fn prop_ppsm_equivariance_under_any_permutation() {
    // Softmax(Xπ) == Softmax(X)π for every random π — the identity that
    // makes Π_PPSM sound.
    check("ppsm equivariance", 12, |g| {
        let mut mpc = mk();
        let mut be = NativeBackend::new();
        let mut views = Views::new(false);
        let n = 2 + g.below(14);
        let rows = 1 + g.below(4);
        let x = FloatTensor::from_vec(rows, n, g.vec_small_f64(rows * n).iter().map(|&v| v as f32 * 0.4).collect());
        let p = Perm::random(n, g.rng());
        let sh = mpc.share_local(&fixed::encode_tensor(&p.apply_cols(&x)));
        let out = nonlin::pp_softmax(&mut mpc, &mut be, &mut views, &sh, "prop").unwrap();
        let got = fixed::decode_tensor(&out.reconstruct());
        let mut want = x.clone();
        for r in 0..rows {
            centaur::runtime::native::softmax_row(want.row_mut(r));
        }
        assert!(got.max_abs_diff(&p.apply_cols(&want)) < 2e-3);
    });
}

#[test]
fn prop_ppp_composes_with_inverse() {
    check("ppp inverse composition", 10, |g| {
        let mut mpc = mk();
        let n = 2 + g.below(10);
        let p = Perm::random(n, g.rng());
        let x = RingTensor::from_vec(3, n, (0..3 * n).map(|i| fixed::encode(i as f64 * 0.01)).collect());
        let sx = mpc.share_local(&x);
        let pi = ppp::share_perm(&mut mpc, &p, OpClass::Linear);
        let pinv = ppp::share_perm(&mut mpc, &p.inverse(), OpClass::Linear);
        let fwd = ppp::ppp_cols(&mut mpc, &sx, &pi, OpClass::Linear);
        let back = ppp::ppp_cols(&mut mpc, &fwd, &pinv, OpClass::Linear);
        let got = fixed::decode_tensor(&back.reconstruct());
        let want = fixed::decode_tensor(&x);
        assert!(got.max_abs_diff(&want) < 0.01);
    });
}

#[test]
fn prop_smpc_exp_monotone_and_bounded() {
    check("smpc exp sane", 20, |g| {
        let mut mpc = mk();
        let a = g.f64_in(-8.0, 0.0);
        let b = g.f64_in(-8.0, 0.0);
        let x = FloatTensor::from_vec(1, 2, vec![a.min(b) as f32, a.max(b) as f32]);
        let sh = mpc.share_local(&fixed::encode_tensor(&x));
        let e = fixed::decode_tensor(&smpc::exp(&mut mpc, &sh, OpClass::Softmax).reconstruct());
        assert!(e.get(0, 0) <= e.get(0, 1) + 0.02, "exp monotonicity");
        assert!(e.get(0, 1) <= 1.05, "exp(x<=0) <= 1");
        assert!(e.get(0, 0) >= -0.02);
    });
}

#[test]
fn prop_trunc_error_bounded_through_scalmul_chain() {
    // Chains of Π_ScalMul keep fixed-point error linear in depth.
    check("scalmul chain error", 8, |g| {
        let mut mpc = mk();
        let n = 4 + g.below(8);
        let x = FloatTensor::from_vec(1, n, g.vec_small_f64(n).iter().map(|&v| v as f32 * 0.1).collect());
        let w = FloatTensor::from_vec(n, n, g.vec_small_f64(n * n).iter().map(|&v| v as f32 * 0.05).collect());
        let w_fx = fixed::encode_tensor(&w);
        let mut sh = mpc.share_local(&fixed::encode_tensor(&x));
        let mut want = x.clone();
        for _ in 0..4 {
            sh = mpc.scalmul_nt(&sh, &w_fx, OpClass::Linear);
            want = want.matmul_nt(&w);
        }
        let got = fixed::decode_tensor(&sh.reconstruct());
        assert!(got.max_abs_diff(&want) < 0.01, "chain diff {}", got.max_abs_diff(&want));
    });
}

#[test]
fn prop_ledger_total_is_sum_of_classes() {
    check("ledger consistency", 30, |g| {
        let mut net = NetSim::new(NetworkProfile::wan2());
        let mut expect_bytes = 0u64;
        let mut expect_rounds = 0u64;
        for _ in 0..g.below(20) {
            let class = *g.rng().choose(&OpClass::ALL);
            let bytes = g.below(10_000) as u64;
            net.charge_bytes(class, bytes);
            net.round(class, 1);
            expect_bytes += bytes;
            expect_rounds += 1;
        }
        assert_eq!(net.ledger.bytes_total(), expect_bytes);
        assert_eq!(net.ledger.rounds_total(), expect_rounds);
        let t: f64 = OpClass::ALL.iter().map(|&c| net.ledger.class_time(c, &net.profile)).sum();
        assert!((t - net.ledger.total_time(&net.profile)).abs() < 1e-9);
    });
}

#[test]
fn prop_onehot_scalmul_is_lookup() {
    check("onehot lookup", 15, |g| {
        let mut mpc = mk();
        let vocab = 8 + g.below(24);
        let d = 4 + g.below(12);
        let w = FloatTensor::from_vec(vocab, d, g.vec_small_f64(vocab * d).iter().map(|&v| v as f32 * 0.1).collect());
        let tok = g.below(vocab) as u32;
        let onehot = centaur::protocols::embedding::one_hot_fx(&[tok], vocab);
        let sh = mpc.share_local(&onehot);
        let out = mpc.scalmul_rhs(&sh, &fixed::encode_tensor(&w), OpClass::Embedding);
        let got = fixed::decode_tensor(&out.reconstruct());
        for c in 0..d {
            assert!((got.get(0, c) - w.get(tok as usize, c)).abs() < 1e-3);
        }
    });
}
