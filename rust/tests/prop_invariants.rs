//! Property-based invariants across module boundaries (the crate's
//! substitute for proptest; see rust/src/util/prop.rs).

use centaur::engine::views::Views;
use centaur::fixed;
use centaur::mpc::{nonlin as smpc, Mpc};
use centaur::net::{NetSim, NetworkProfile, OpClass};
use centaur::perm::Perm;
use centaur::protocols::{nonlin, ppp};
use centaur::ring;
use centaur::runtime::NativeBackend;
use centaur::tensor::{FloatTensor, RingTensor};
use centaur::util::prop::check;

fn mk() -> Mpc {
    Mpc::new(NetSim::new(NetworkProfile::lan()), 0xBEEF)
}

#[test]
fn prop_share_algebra_is_ring_homomorphic() {
    check("share homomorphism", 60, |g| {
        let mut mpc = mk();
        let n = g.dim(24);
        let x = RingTensor::from_vec(1, n, g.vec_i64(n));
        let y = RingTensor::from_vec(1, n, g.vec_i64(n));
        let sx = mpc.share_local(&x);
        let sy = mpc.share_local(&y);
        assert_eq!(mpc.add(&sx, &sy).reconstruct(), ring::add(&x, &y));
        assert_eq!(mpc.sub(&sx, &sy).reconstruct(), ring::sub(&x, &y));
        let p = RingTensor::from_vec(1, n, g.vec_i64(n));
        assert_eq!(mpc.add_plain(&sx, &p).reconstruct(), ring::add(&x, &p));
    });
}

#[test]
fn prop_beaver_matmul_correct_for_any_shape() {
    check("beaver matmul", 15, |g| {
        let mut mpc = mk();
        let (m, k, n) = (g.dim(6), g.dim(8), g.dim(6));
        let a = FloatTensor::from_vec(m, k, g.vec_small_f64(m * k).iter().map(|&v| v as f32 * 0.1).collect());
        let b = FloatTensor::from_vec(k, n, g.vec_small_f64(k * n).iter().map(|&v| v as f32 * 0.1).collect());
        let sa = mpc.share_local(&fixed::encode_tensor(&a));
        let sb = mpc.share_local(&fixed::encode_tensor(&b));
        let got = fixed::decode_tensor(&mpc.matmul(&sa, &sb, OpClass::Linear).reconstruct());
        let want = a.matmul(&b);
        assert!(got.max_abs_diff(&want) < 0.02, "diff {}", got.max_abs_diff(&want));
    });
}

#[test]
fn prop_ppsm_equivariance_under_any_permutation() {
    // Softmax(Xπ) == Softmax(X)π for every random π — the identity that
    // makes Π_PPSM sound.
    check("ppsm equivariance", 12, |g| {
        let mut mpc = mk();
        let mut be = NativeBackend::new();
        let mut views = Views::new(false);
        let n = 2 + g.below(14);
        let rows = 1 + g.below(4);
        let x = FloatTensor::from_vec(rows, n, g.vec_small_f64(rows * n).iter().map(|&v| v as f32 * 0.4).collect());
        let p = Perm::random(n, g.rng());
        let sh = mpc.share_local(&fixed::encode_tensor(&p.apply_cols(&x)));
        let out = nonlin::pp_softmax(&mut mpc, &mut be, &mut views, &sh, "prop").unwrap();
        let got = fixed::decode_tensor(&out.reconstruct());
        let mut want = x.clone();
        for r in 0..rows {
            centaur::runtime::native::softmax_row(want.row_mut(r));
        }
        assert!(got.max_abs_diff(&p.apply_cols(&want)) < 2e-3);
    });
}

#[test]
fn prop_ppp_composes_with_inverse() {
    check("ppp inverse composition", 10, |g| {
        let mut mpc = mk();
        let n = 2 + g.below(10);
        let p = Perm::random(n, g.rng());
        let x = RingTensor::from_vec(3, n, (0..3 * n).map(|i| fixed::encode(i as f64 * 0.01)).collect());
        let sx = mpc.share_local(&x);
        let pi = ppp::share_perm(&mut mpc, &p, OpClass::Linear);
        let pinv = ppp::share_perm(&mut mpc, &p.inverse(), OpClass::Linear);
        let fwd = ppp::ppp_cols(&mut mpc, &sx, &pi, OpClass::Linear);
        let back = ppp::ppp_cols(&mut mpc, &fwd, &pinv, OpClass::Linear);
        let got = fixed::decode_tensor(&back.reconstruct());
        let want = fixed::decode_tensor(&x);
        assert!(got.max_abs_diff(&want) < 0.01);
    });
}

#[test]
fn prop_fixed_rhs_correlated_matmul_equals_plain_beaver() {
    // Fixed-operand triple algebra (ISSUE 4): for random shapes, seeds and
    // use counts, the correlated-open matmul against a session-fixed right
    // operand reconstructs to the same product as the plain Beaver matmul
    // (share-for-share: both are valid sharings of X·Y, equal up to the
    // per-share fixed-point truncation LSB), with only the varying
    // operand's mask difference opened per use.
    use centaur::mpc::TripleShape;
    check("fixed-rhs correlated == plain beaver", 10, |g| {
        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 0xF1 ^ g.case as u64);
        let (m, n) = (g.dim(5), 2 + g.below(8));
        let uses = 1 + g.below(4);
        let y = FloatTensor::from_vec(
            n,
            n,
            g.vec_small_f64(n * n).iter().map(|&v| v as f32 * 0.1).collect(),
        );
        let sy = mpc.share_local(&fixed::encode_tensor(&y));
        let mut corr = mpc.dealer.fixed_correlation(TripleShape::fixed_ppp(m, n, uses));
        let f = mpc.open_fixed_operand(&sy, &mut corr, OpClass::Other).unwrap();
        for _ in 0..uses {
            let x = FloatTensor::from_vec(
                m,
                n,
                g.vec_small_f64(m * n).iter().map(|&v| v as f32 * 0.1).collect(),
            );
            let sx = mpc.share_local(&fixed::encode_tensor(&x));
            let bytes_before = mpc.net.ledger.class(OpClass::Linear).bytes;
            let corr_out = mpc.matmul_fixed_rhs(&sx, &f, &mut corr, OpClass::Linear).unwrap();
            let corr_bytes = mpc.net.ledger.class(OpClass::Linear).bytes - bytes_before;
            let bytes_before = mpc.net.ledger.class(OpClass::Linear).bytes;
            let plain_out = mpc.matmul(&sx, &sy, OpClass::Linear);
            let plain_bytes = mpc.net.ledger.class(OpClass::Linear).bytes - bytes_before;
            // exact byte contract: E only, vs E + F
            assert_eq!(corr_bytes, 2 * 8 * (m * n) as u64);
            assert_eq!(plain_bytes, 2 * 8 * (m * n + n * n) as u64);
            let got = fixed::decode_tensor(&corr_out.reconstruct());
            let want = fixed::decode_tensor(&plain_out.reconstruct());
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "correlated vs plain diff {}",
                got.max_abs_diff(&want)
            );
        }
        // reuse beyond the dealt use count errors, never reuses a mask
        let sx = mpc.share_local(&RingTensor::zeros(m, n));
        assert!(mpc.matmul_fixed_rhs(&sx, &f, &mut corr, OpClass::Linear).is_err());
    });
}

#[test]
fn prop_fixed_lhs_and_grown_families_match_plain_beaver() {
    use centaur::mpc::{Share, TripleShape};
    check("fixed-lhs/grown correlated == plain beaver", 8, |g| {
        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 0xF2 ^ g.case as u64);
        let n = 2 + g.below(6);
        let heads = 1 + g.below(2);
        let d = heads * (1 + g.below(4));
        let uses = 1 + g.below(n);

        // left-fixed column-per-use (the KV outer product)
        let x = FloatTensor::from_vec(
            n,
            n,
            g.vec_small_f64(n * n).iter().map(|&v| v as f32 * 0.1).collect(),
        );
        let sx = mpc.share_local(&fixed::encode_tensor(&x));
        let mut app = mpc.dealer.fixed_correlation(TripleShape::fixed_append(n, d, uses));
        let f = mpc.open_fixed_operand(&sx, &mut app, OpClass::Other).unwrap();
        for pos in 0..uses {
            let yv = FloatTensor::from_vec(
                1,
                d,
                g.vec_small_f64(d).iter().map(|&v| v as f32 * 0.1).collect(),
            );
            let sy = mpc.share_local(&fixed::encode_tensor(&yv));
            let corr_out = mpc.matmul_fixed_lhs_col(&f, &sy, &mut app, pos, OpClass::Linear).unwrap();
            let col = sx.col_block(pos, pos + 1);
            let plain_out = mpc.matmul(&col, &sy, OpClass::Linear);
            let got = fixed::decode_tensor(&corr_out.reconstruct());
            let want = fixed::decode_tensor(&plain_out.reconstruct());
            assert!(got.max_abs_diff(&want) < 1e-3, "lhs-col pos {pos}");
        }
        let sy = mpc.share_local(&RingTensor::zeros(1, d));
        assert!(mpc.matmul_fixed_lhs_col(&f, &sy, &mut app, uses, OpClass::Linear).is_err());

        // row-grown scores (the write-once K cache)
        let mut grown = mpc.dealer.fixed_correlation(TripleShape::fixed_scores(heads, n, d, uses));
        let mut k_cache = Share { s0: RingTensor::zeros(n, d), s1: RingTensor::zeros(n, d) };
        let mut f_rows = RingTensor::zeros(n, d);
        let dh = d / heads;
        for pos in 0..uses {
            let row = FloatTensor::from_vec(
                1,
                d,
                g.vec_small_f64(d).iter().map(|&v| v as f32 * 0.1).collect(),
            );
            let row_sh = mpc.share_local(&fixed::encode_tensor(&row));
            k_cache.s0.row_mut(pos).copy_from_slice(row_sh.s0.row(0));
            k_cache.s1.row_mut(pos).copy_from_slice(row_sh.s1.row(0));
            let opened =
                mpc.open_fixed_grown_row(&row_sh, &mut grown, pos, OpClass::Linear).unwrap();
            f_rows.row_mut(pos).copy_from_slice(opened.row(0));

            let q = FloatTensor::from_vec(
                1,
                d,
                g.vec_small_f64(d).iter().map(|&v| v as f32 * 0.1).collect(),
            );
            let sq = mpc.share_local(&fixed::encode_tensor(&q));
            let outs = mpc
                .matmul_fixed_grown_scores(&sq, &f_rows, &mut grown, pos, n, OpClass::Linear)
                .unwrap();
            for (h, out) in outs.iter().enumerate() {
                let qh = sq.col_block(h * dh, (h + 1) * dh);
                let kht = k_cache.col_block(h * dh, (h + 1) * dh).transpose();
                let plain = mpc.matmul(&qh, &kht, OpClass::Linear);
                let got = fixed::decode_tensor(&out.reconstruct());
                let want = fixed::decode_tensor(&plain.reconstruct());
                assert!(got.max_abs_diff(&want) < 1e-3, "grown pos {pos} head {h}");
            }
        }
        // the session masks were each opened exactly once per element
        assert_eq!(app.openings(), 1);
        assert_eq!(grown.openings(), uses as u64);
    });
}

#[test]
fn prop_batched_openings_equal_sequential_share_for_share() {
    // The batched-opening engine (Mpc::begin_batch/flush_batch, DESIGN.md
    // §Batched openings): for random shapes and seeds, running independent
    // opening protocols inside one batch produces *share-for-share
    // identical* results to the sequential schedule (two identically
    // seeded contexts consume identical dealer/PRG streams), moves
    // identical bytes, and collapses the rounds to exactly one.
    check("batched == sequential openings", 12, |g| {
        let seed = 0xBA7C4 ^ (g.case as u64).wrapping_mul(6151);
        let mut seq = Mpc::new(NetSim::new(NetworkProfile::lan()), seed);
        let mut bat = Mpc::new(NetSim::new(NetworkProfile::lan()), seed);
        let ops = 1 + g.below(4);
        // Identical inputs, shared in identical order in both contexts so
        // every mask/triple draw lines up.
        let mut inputs = Vec::new();
        for _ in 0..ops {
            let (m, k, n) = (g.dim(4), g.dim(5), g.dim(4));
            let x = RingTensor::from_vec(m, k, g.vec_i64(m * k).iter().map(|v| v >> 20).collect());
            let y = RingTensor::from_vec(k, n, g.vec_i64(k * n).iter().map(|v| v >> 20).collect());
            inputs.push((x, y));
        }
        let seq_shares: Vec<_> =
            inputs.iter().map(|(x, y)| (seq.share_local(x), seq.share_local(y))).collect();
        let bat_shares: Vec<_> =
            inputs.iter().map(|(x, y)| (bat.share_local(x), bat.share_local(y))).collect();

        let seq_outs: Vec<_> =
            seq_shares.iter().map(|(sx, sy)| seq.matmul(sx, sy, OpClass::Linear)).collect();
        bat.begin_batch();
        let bat_outs: Vec<_> =
            bat_shares.iter().map(|(sx, sy)| bat.matmul(sx, sy, OpClass::Linear)).collect();
        assert_eq!(bat.net.ledger.rounds_total(), 0, "rounds must defer until the flush");
        assert_eq!(bat.flush_batch(OpClass::Linear), 1);

        for (i, (s, b)) in seq_outs.iter().zip(bat_outs.iter()).enumerate() {
            assert_eq!(s.s0, b.s0, "op {i}: P0 share differs under batching");
            assert_eq!(s.s1, b.s1, "op {i}: P1 share differs under batching");
        }
        assert_eq!(
            seq.net.ledger.bytes_total(),
            bat.net.ledger.bytes_total(),
            "batching must not move a single extra byte"
        );
        assert_eq!(seq.net.ledger.rounds_total(), ops as u64);
        assert_eq!(bat.net.ledger.rounds_total(), 1);

        // Flushing an empty batch is a no-op.
        let before = bat.net.ledger.rounds_total();
        bat.begin_batch();
        assert_eq!(bat.flush_batch(OpClass::Linear), 0);
        assert_eq!(bat.net.ledger.rounds_total(), before);
    });
}

#[test]
fn prop_deferred_pp_conversions_match_rounded_twins() {
    // The unrounded Π_PPLN/Π_PPGeLU used by the fused decode tail must be
    // transfer-for-transfer and share-for-share identical to their
    // round-charging twins — only the round placement may differ.
    check("unrounded pp == rounded pp", 10, |g| {
        let seed = 0x9933 ^ (g.case as u64).wrapping_mul(7877);
        let mut a = Mpc::new(NetSim::new(NetworkProfile::lan()), seed);
        let mut b = Mpc::new(NetSim::new(NetworkProfile::lan()), seed);
        let mut be_a = NativeBackend::new();
        let mut be_b = NativeBackend::new();
        let mut va = Views::new(false);
        let mut vb = Views::new(false);
        let d = 2 + g.below(12);
        let x = FloatTensor::from_vec(
            1,
            d,
            g.vec_small_f64(d).iter().map(|&v| v as f32 * 0.2).collect(),
        );
        let gamma: Vec<f32> = (0..d).map(|i| 1.0 + 0.01 * i as f32).collect();
        let beta: Vec<f32> = (0..d).map(|i| -0.02 * i as f32).collect();
        let sx_a = a.share_local(&fixed::encode_tensor(&x));
        let sx_b = b.share_local(&fixed::encode_tensor(&x));
        let out_a = nonlin::pp_layernorm(
            &mut a, &mut be_a, &mut va, &sx_a, &gamma, &beta, OpClass::LayerNorm, "rounded",
        )
        .unwrap();
        let out_b = nonlin::pp_layernorm_unrounded(
            &mut b, &mut be_b, &mut vb, &sx_b, &gamma, &beta, OpClass::LayerNorm, "unrounded",
        )
        .unwrap();
        assert_eq!(out_a.s0, out_b.s0);
        assert_eq!(out_a.s1, out_b.s1);
        assert_eq!(a.net.ledger.bytes_total(), b.net.ledger.bytes_total());
        assert_eq!(a.net.ledger.rounds_total(), 2);
        assert_eq!(b.net.ledger.rounds_total(), 0, "unrounded twin defers rounds to the caller");
    });
}

#[test]
fn prop_smpc_exp_monotone_and_bounded() {
    check("smpc exp sane", 20, |g| {
        let mut mpc = mk();
        let a = g.f64_in(-8.0, 0.0);
        let b = g.f64_in(-8.0, 0.0);
        let x = FloatTensor::from_vec(1, 2, vec![a.min(b) as f32, a.max(b) as f32]);
        let sh = mpc.share_local(&fixed::encode_tensor(&x));
        let e = fixed::decode_tensor(&smpc::exp(&mut mpc, &sh, OpClass::Softmax).reconstruct());
        assert!(e.get(0, 0) <= e.get(0, 1) + 0.02, "exp monotonicity");
        assert!(e.get(0, 1) <= 1.05, "exp(x<=0) <= 1");
        assert!(e.get(0, 0) >= -0.02);
    });
}

#[test]
fn prop_trunc_error_bounded_through_scalmul_chain() {
    // Chains of Π_ScalMul keep fixed-point error linear in depth.
    check("scalmul chain error", 8, |g| {
        let mut mpc = mk();
        let n = 4 + g.below(8);
        let x = FloatTensor::from_vec(1, n, g.vec_small_f64(n).iter().map(|&v| v as f32 * 0.1).collect());
        let w = FloatTensor::from_vec(n, n, g.vec_small_f64(n * n).iter().map(|&v| v as f32 * 0.05).collect());
        let w_fx = fixed::encode_tensor(&w);
        let mut sh = mpc.share_local(&fixed::encode_tensor(&x));
        let mut want = x.clone();
        for _ in 0..4 {
            sh = mpc.scalmul_nt(&sh, &w_fx, OpClass::Linear);
            want = want.matmul_nt(&w);
        }
        let got = fixed::decode_tensor(&sh.reconstruct());
        assert!(got.max_abs_diff(&want) < 0.01, "chain diff {}", got.max_abs_diff(&want));
    });
}

#[test]
fn prop_ledger_total_is_sum_of_classes() {
    check("ledger consistency", 30, |g| {
        let mut net = NetSim::new(NetworkProfile::wan2());
        let mut expect_bytes = 0u64;
        let mut expect_rounds = 0u64;
        for _ in 0..g.below(20) {
            let class = *g.rng().choose(&OpClass::ALL);
            let bytes = g.below(10_000) as u64;
            net.charge_bytes(class, bytes);
            net.round(class, 1);
            expect_bytes += bytes;
            expect_rounds += 1;
        }
        assert_eq!(net.ledger.bytes_total(), expect_bytes);
        assert_eq!(net.ledger.rounds_total(), expect_rounds);
        let t: f64 = OpClass::ALL.iter().map(|&c| net.ledger.class_time(c, &net.profile)).sum();
        assert!((t - net.ledger.total_time(&net.profile)).abs() < 1e-9);
    });
}

#[test]
fn prop_onehot_scalmul_is_lookup() {
    check("onehot lookup", 15, |g| {
        let mut mpc = mk();
        let vocab = 8 + g.below(24);
        let d = 4 + g.below(12);
        let w = FloatTensor::from_vec(vocab, d, g.vec_small_f64(vocab * d).iter().map(|&v| v as f32 * 0.1).collect());
        let tok = g.below(vocab) as u32;
        let onehot = centaur::protocols::embedding::one_hot_fx(&[tok], vocab);
        let sh = mpc.share_local(&onehot);
        let out = mpc.scalmul_rhs(&sh, &fixed::encode_tensor(&w), OpClass::Embedding);
        let got = fixed::decode_tensor(&out.reconstruct());
        for c in 0..d {
            assert!((got.get(0, c) - w.get(tok as usize, c)).abs() < 1e-3);
        }
    });
}
