//! Cross-module integration tests: Centaur engine vs plaintext oracle,
//! framework cost relationships, and the XLA/PJRT backend (artifact-gated).

use centaur::baselines::{smpc::SmpcEngine, FrameworkKind, PptiFramework};
use centaur::engine::{CentaurEngine, EngineOptions};
use centaur::model::{forward, ModelConfig, ModelWeights, Variant};
use centaur::net::{NetworkProfile, OpClass};
use centaur::runtime::{Backend, NativeBackend, XlaBackend};
use centaur::tensor::FloatTensor;
use centaur::util::rng::Rng;

fn tokens_for(cfg: &ModelConfig, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    (0..cfg.n_ctx).map(|_| (rng.below(cfg.vocab - 4) + 4) as u32).collect()
}

fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[test]
fn centaur_equals_plaintext_bert_and_gpt() {
    for (cfg, seed) in [(ModelConfig::bert_tiny(), 1u64), (ModelConfig::gpt2_tiny(), 2u64)] {
        let w = ModelWeights::random(&cfg, seed);
        let toks = tokens_for(&cfg, seed + 10);
        let mut eng = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), seed).unwrap();
        let got = eng.infer(&toks).unwrap().logits;
        let want = forward(&cfg, &w, &toks, Variant::Exact);
        // compare the decision-relevant rows
        let r = got.rows() - 1;
        for c in 0..got.cols().min(16) {
            assert!(
                (got.get(r, c) - want.get(r, c)).abs() < 0.08,
                "{}: logit[{r},{c}] {} vs {}",
                cfg.name,
                got.get(r, c),
                want.get(r, c)
            );
        }
        assert!(eng.leaks().is_empty(), "{}: leaks {:?}", cfg.name, eng.leaks());
    }
}

#[test]
fn permutations_change_shares_not_results() {
    // Two engines with different permutation seeds produce the same logits.
    let cfg = ModelConfig::bert_tiny();
    let w = ModelWeights::random(&cfg, 3);
    let toks = tokens_for(&cfg, 4);
    let mut e1 = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 100).unwrap();
    let mut e2 = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 200).unwrap();
    let a = e1.infer(&toks).unwrap().logits;
    let b = e2.infer(&toks).unwrap().logits;
    assert!(a.max_abs_diff(&b) < 0.05, "diff {}", a.max_abs_diff(&b));
}

#[test]
fn linear_layer_communication_halved_vs_baselines() {
    // Paper §7.3.1: Centaur's linear-layer traffic is about half the
    // baselines' (Π_ScalMul is free; only attention products remain).
    let cfg = ModelConfig::bert_tiny();
    let w = ModelWeights::random(&cfg, 5);
    let toks = tokens_for(&cfg, 6);
    let mut cent = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 7).unwrap();
    let c = cent.infer(&toks).unwrap().stats;
    let mut puma = SmpcEngine::new(FrameworkKind::Puma, &cfg, &w, NetworkProfile::lan(), 7).unwrap();
    let p = puma.infer(&toks).unwrap().stats;
    let c_lin = c.class(OpClass::Linear).bytes as f64;
    let p_lin = p.class(OpClass::Linear).bytes as f64;
    assert!(
        p_lin / c_lin > 1.3,
        "linear traffic: puma {} vs centaur {} (ratio {:.2})",
        p_lin,
        c_lin,
        p_lin / c_lin
    );
}

#[test]
fn nonlinear_speedup_vs_puma_is_order_of_magnitude() {
    let cfg = ModelConfig::bert_tiny();
    let w = ModelWeights::random(&cfg, 8);
    let toks = tokens_for(&cfg, 9);
    let mut cent = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 10).unwrap();
    let c = cent.infer(&toks).unwrap().stats;
    let mut puma = SmpcEngine::new(FrameworkKind::Puma, &cfg, &w, NetworkProfile::lan(), 10).unwrap();
    let p = puma.infer(&toks).unwrap().stats;
    let nl = |l: &centaur::net::CostLedger| {
        (l.class(OpClass::Softmax).bytes + l.class(OpClass::Gelu).bytes + l.class(OpClass::LayerNorm).bytes) as f64
    };
    let ratio = nl(&p) / nl(&c);
    assert!(ratio > 5.0, "non-linear comm ratio only {ratio:.1}");
}

#[test]
fn xla_backend_matches_native_ops() {
    if !artifacts_present() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = ModelConfig::bert_tiny();
    let mut xla = XlaBackend::new("artifacts", &cfg.name).expect("xla backend");
    let mut native = NativeBackend::new();
    // softmax at the artifact shape (h·n, n)
    let x = FloatTensor::from_fn(cfg.h * cfg.n_ctx, cfg.n_ctx, |r, c| ((r * 7 + c) % 19) as f32 * 0.3 - 2.0);
    let a = xla.softmax(&x).unwrap();
    let b = native.softmax(&x).unwrap();
    assert!(a.max_abs_diff(&b) < 1e-4, "softmax diff {}", a.max_abs_diff(&b));
    // gelu at (n, k)
    let g = FloatTensor::from_fn(cfg.n_ctx, cfg.k, |r, c| ((r + c) % 13) as f32 * 0.4 - 2.5);
    let a = xla.gelu(&g).unwrap();
    let b = native.gelu(&g).unwrap();
    assert!(a.max_abs_diff(&b) < 1e-4, "gelu diff {}", a.max_abs_diff(&b));
    // layernorm at (n, d)
    let l = FloatTensor::from_fn(cfg.n_ctx, cfg.d, |r, c| ((r * 3 + c) % 11) as f32 * 0.5 - 2.0);
    let gamma: Vec<f32> = (0..cfg.d).map(|i| 1.0 + i as f32 * 0.01).collect();
    let beta: Vec<f32> = (0..cfg.d).map(|i| i as f32 * -0.01).collect();
    let a = xla.layernorm(&l, &gamma, &beta).unwrap();
    let b = native.layernorm(&l, &gamma, &beta).unwrap();
    assert!(a.max_abs_diff(&b) < 1e-3, "ln diff {}", a.max_abs_diff(&b));
    assert_eq!(xla.fallbacks(), 0, "all ops must come from artifacts");
    assert!(xla.compiled_count() >= 3);
}

#[test]
fn xla_ring_matmul_matches_native() {
    if !artifacts_present() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut xla = XlaBackend::new("artifacts", "bert-tiny").expect("xla backend");
    let mut rng = Rng::new(55);
    let a = centaur::tensor::RingTensor::from_vec(32, 64, rng.vec_i64(32 * 64));
    let b = centaur::tensor::RingTensor::from_vec(64, 64, rng.vec_i64(64 * 64));
    let got = xla.ring_matmul(&a, &b).unwrap().expect("artifact for 32x64x64");
    let want = centaur::ring::matmul(&a, &b);
    assert_eq!(got, want, "wrapping s64 matmul via PJRT must be exact");
}

#[test]
fn centaur_engine_runs_on_xla_backend() {
    if !artifacts_present() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = ModelConfig::bert_tiny();
    let w = ModelWeights::random(&cfg, 12);
    let toks = tokens_for(&cfg, 13);
    let want = forward(&cfg, &w, &toks, Variant::Exact);
    let backend = Box::new(XlaBackend::new("artifacts", &cfg.name).unwrap());
    let mut eng = CentaurEngine::with_backend(
        &cfg,
        &w,
        backend,
        EngineOptions {
            profile: NetworkProfile::lan(),
            seed: 14,
            record_views: false,
            fast_sim: false,
            ..Default::default()
        },
    )
    .unwrap();
    let got = eng.infer(&toks).unwrap().logits;
    assert!(got.max_abs_diff(&want) < 0.08, "xla-backend engine diff {}", got.max_abs_diff(&want));
    assert_eq!(eng.backend_fallbacks(), 0, "engine must hit AOT artifacts only");
}
