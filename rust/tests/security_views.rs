//! Security-model tests: the leak detector, failure injection, and the
//! statistical properties of what each party observes (DESIGN.md §Security).

use centaur::baselines::{permonly::PermOnlyEngine, PptiFramework};
use centaur::engine::views::PermTag;
use centaur::engine::{CentaurEngine, EngineOptions};
use centaur::model::{forward_trace, ModelConfig, ModelWeights, PermSet, Variant};
use centaur::net::NetworkProfile;
use centaur::runtime::NativeBackend;
use centaur::util::rng::Rng;

fn toks(cfg: &ModelConfig, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    (0..cfg.n_ctx).map(|_| (rng.below(cfg.vocab - 4) + 4) as u32).collect()
}

#[test]
fn centaur_p1_sees_only_permuted_tensors() {
    let cfg = ModelConfig::bert_tiny();
    let w = ModelWeights::random(&cfg, 21);
    let mut eng = CentaurEngine::with_backend(
        &cfg,
        &w,
        Box::new(NativeBackend::new()),
        EngineOptions { record_views: true, seed: 22, ..Default::default() },
    )
    .unwrap();
    eng.infer(&toks(&cfg, 23)).unwrap();
    assert!(eng.leaks().is_empty());
    // every view carries a permutation tag
    for v in &eng.views.p1 {
        assert_ne!(v.tag, PermTag::None, "view {} untagged", v.label);
    }
    // expected observation count: embedding LN + per layer (softmax, 2 LN,
    // gelu) + pooler tanh
    assert_eq!(eng.views.p1.len(), 1 + 4 * cfg.layers + 1);
}

#[test]
fn permuted_o1_differs_from_plaintext_o1_but_is_its_permutation() {
    // Failure-injection-style consistency: the tensor P1 sees must be a
    // column permutation of the true O1 — nothing more, nothing less.
    let cfg = ModelConfig::bert_tiny();
    let w = ModelWeights::random(&cfg, 31);
    let t = toks(&cfg, 32);
    let mut eng = CentaurEngine::with_backend(
        &cfg,
        &w,
        Box::new(NativeBackend::new()),
        EngineOptions { record_views: true, seed: 33, ..Default::default() },
    )
    .unwrap();
    eng.infer(&t).unwrap();
    let seen = eng.views.find("O1pi1 layer0").unwrap().tensor.clone().unwrap();
    let truth = forward_trace(&cfg, &w, &t, Variant::Exact).layers[0].o1.clone();
    // not equal as-is (the permutation is non-trivial with high prob.)
    assert!(seen.max_abs_diff(&truth) > 0.01);
    // but equal after undoing π₁ on columns
    let unperm = eng.perms().pi1.inverse().apply_cols(&seen);
    assert!(
        unperm.max_abs_diff(&truth) < 0.05,
        "P1's O1 view must be exactly O1·π₁ (diff {})",
        unperm.max_abs_diff(&truth)
    );
}

#[test]
fn identity_permutation_injection_is_detected_as_leak_risk() {
    // Ablation / failure injection: with identity permutations the "permuted"
    // views equal the plaintext intermediates — the situation the paper's
    // §3 warns about. We detect it by direct comparison.
    let cfg = ModelConfig::bert_tiny();
    let w = ModelWeights::random(&cfg, 41);
    let t = toks(&cfg, 42);
    let mut eng = CentaurEngine::with_perms(
        &cfg,
        &w,
        Box::new(NativeBackend::new()),
        EngineOptions { record_views: true, seed: 43, ..Default::default() },
        PermSet::identity(&cfg),
    )
    .unwrap();
    eng.infer(&t).unwrap();
    let seen = eng.views.find("O1pi1 layer0").unwrap().tensor.clone().unwrap();
    let truth = forward_trace(&cfg, &w, &t, Variant::Exact).layers[0].o1.clone();
    assert!(
        seen.max_abs_diff(&truth) < 0.05,
        "identity perms must reproduce the plaintext (diff {}) — injection works",
        seen.max_abs_diff(&truth)
    );
}

#[test]
fn kv_cache_decode_is_leak_free_and_never_opens_the_cache() {
    // A cached multi-step generate must satisfy the same view discipline as
    // a one-shot inference: P1 only ever reconstructs permuted single-token
    // rows, and the secret-shared `[K]`/`[Ṽ]` cache tensors never appear in
    // its view in any form.
    let cfg = ModelConfig::gpt2_tiny();
    let w = ModelWeights::random(&cfg, 61);
    let mut eng = CentaurEngine::with_backend(
        &cfg,
        &w,
        Box::new(NativeBackend::new()),
        EngineOptions { record_views: true, seed: 62, ..Default::default() },
    )
    .unwrap();
    let prompt = [7u32, 11, 13];
    let steps = 4usize;
    let (gen, cost) = eng.generate(&prompt, steps).unwrap();
    assert_eq!(gen.len(), steps);
    assert!(cost.bytes_total() > 0);

    // 1. No unpermuted plaintext anywhere across the whole cached session.
    assert!(eng.leaks().is_empty(), "leaks: {:?}", eng.leaks());
    for v in &eng.views.p1 {
        assert_ne!(v.tag, PermTag::None, "view {} untagged", v.label);
    }

    // 2. Exactly the expected openings, per absorbed token: embedding LN +
    //    per layer (softmax, LN, GeLU, LN) + final LN — nothing extra that
    //    could carry cache state.
    let absorbs = prompt.len() + steps;
    assert_eq!(eng.views.p1.len(), absorbs * (2 + 4 * cfg.layers));

    // 3. No observation ever has the `(n_ctx, d)` KV-cache shape, and every
    //    decode view is a single-token row: `(h, n_ctx)` scores or `(1, ·)`
    //    activation rows.
    for v in &eng.views.p1 {
        assert!(
            (v.rows, v.cols) != (cfg.n_ctx, cfg.d),
            "view '{}' has the KV-cache shape {}x{}",
            v.label,
            v.rows,
            v.cols
        );
        assert!(v.rows == 1 || v.rows == cfg.h, "view '{}' is not a single-token row", v.label);
    }

    // 4. Decode softmax openings carry the π₁ tag on (h, n_ctx) score rows.
    let sm = eng.views.find("decode O1pi1 layer0 pos0").expect("decode softmax view");
    assert_eq!(sm.tag, PermTag::Pi1);
    assert_eq!((sm.rows, sm.cols), (cfg.h, cfg.n_ctx));
    // and the last step's opening is present too (cache grew to the end)
    let last = format!("decode O1pi1 layer{} pos{}", cfg.layers - 1, absorbs - 1);
    assert!(eng.views.find(&last).is_some(), "missing view {last}");
}

/// ISSUE 4 census: multi-step decode with fixed-operand correlations must
/// (1) open the π₁-side session mask exactly once per session per layer,
/// (2) enumerate exactly the same P1 view census as the plain per-step
/// path (zero additional openings — the correlated openings are masked
/// exchanges, never plaintext reconstructions), and (3) never put a KV
/// tensor in any party's view.
#[test]
fn correlated_decode_census_matches_plain_and_opens_pi1_once_per_layer() {
    use centaur::engine::decoder::DecoderSession;

    let cfg = ModelConfig::gpt2_tiny();
    let w = ModelWeights::random(&cfg, 71);
    let prompt = [7u32, 11, 13];
    let forced = [21u32, 34, 55, 89]; // teacher-forced so both paths align
    let absorbs = prompt.len() + forced.len();

    let run = |decode_correlations: bool| {
        let mut eng = CentaurEngine::with_backend(
            &cfg,
            &w,
            Box::new(NativeBackend::new()),
            EngineOptions { record_views: true, seed: 72, decode_correlations, ..Default::default() },
        )
        .unwrap();
        let (openings, uses_left) = {
            let mut sess = DecoderSession::new(&mut eng, &prompt).unwrap();
            for &t in &forced {
                sess.absorb(t).unwrap();
            }
            (sess.correlation_openings(), sess.correlation_uses_left())
        };
        (eng, openings, uses_left)
    };
    let (corr_eng, corr_openings, corr_uses_left) = run(true);
    let (plain_eng, plain_openings, _) = run(false);

    // (1) π₁-side masks (PPP and the π₁ᵀ append side) opened exactly once
    // per session per layer; K rows opened once per absorb.
    assert_eq!(corr_openings.len(), cfg.layers);
    for (layer, &(ppp, append, k_rows)) in corr_openings.iter().enumerate() {
        assert_eq!(ppp, 1, "layer {layer}: π₁ mask must open exactly once per session");
        assert_eq!(append, 1, "layer {layer}: π₁ᵀ mask must open exactly once per session");
        assert_eq!(k_rows, absorbs as u64, "layer {layer}: one K-row opening per absorb");
    }
    assert!(plain_openings.is_empty(), "the plain path deals no correlations");
    // Per-use masks are consumed one per absorb and never reused: the
    // remaining budget is exactly the undealt tail of the context window.
    for (layer, &(ppp_left, append_left, scores_left)) in corr_uses_left.iter().enumerate() {
        let want = cfg.n_ctx - absorbs;
        assert_eq!((ppp_left, append_left, scores_left), (want, want, want), "layer {layer}");
    }

    // (2) identical view census, record for record: same labels, same
    // permutation tags, same observed shapes — zero additional openings.
    assert!(corr_eng.leaks().is_empty(), "leaks: {:?}", corr_eng.leaks());
    assert_eq!(corr_eng.views.p1.len(), plain_eng.views.p1.len(), "census size must not grow");
    assert_eq!(corr_eng.views.p1.len(), absorbs * (2 + 4 * cfg.layers));
    for (c, p) in corr_eng.views.p1.iter().zip(plain_eng.views.p1.iter()) {
        assert_eq!(c.label, p.label, "census labels must match the plain path");
        assert_eq!(c.tag, p.tag);
        assert_eq!((c.rows, c.cols), (p.rows, p.cols));
        assert_ne!(c.tag, PermTag::None, "view {} untagged", c.label);
    }

    // (3) no observation carries a KV-cache tensor: every decode view is a
    // single-token row or an (h, n_ctx) permuted score row.
    for v in &corr_eng.views.p1 {
        assert!(
            (v.rows, v.cols) != (cfg.n_ctx, cfg.d),
            "view '{}' has the KV-cache shape {}x{}",
            v.label,
            v.rows,
            v.cols
        );
        assert!(v.rows == 1 || v.rows == cfg.h, "view '{}' is not a single-token row", v.label);
    }
}

/// ISSUE 5 census: the batched-opening decode schedule (DESIGN.md
/// §Batched openings) must move **exactly** the payloads the sequential
/// schedule moves — batching may merge rounds, never add, drop, or alter
/// an opening. Both runs are identically seeded, so the multiset of
/// transferred payloads (sender, receiver, class, size, digest) and the
/// record-for-record P1 view census — the plaintexts each party sees —
/// must match bit-exactly, while rounds shrink and bytes stay identical.
#[test]
fn batched_decode_census_is_exactly_the_sequential_census() {
    use centaur::engine::decoder::DecoderSession;

    let cfg = ModelConfig::gpt2_tiny();
    let w = ModelWeights::random(&cfg, 91);
    let prompt = [7u32, 11, 13];
    let forced = [21u32, 34, 55];

    let run = |round_batching: bool| {
        let mut eng = CentaurEngine::with_backend(
            &cfg,
            &w,
            Box::new(NativeBackend::new()),
            EngineOptions {
                record_views: true,
                record_transfers: true,
                seed: 92,
                round_batching,
                ..Default::default()
            },
        )
        .unwrap();
        let (prefill_rounds, decode_rounds, bytes) = {
            let mut sess = DecoderSession::new(&mut eng, &prompt).unwrap();
            for &t in &forced {
                sess.absorb(t).unwrap();
            }
            (
                sess.prefill_cost().rounds_total(),
                sess.decode_cost().rounds_total(),
                sess.total_cost().bytes_total(),
            )
        };
        (eng, prefill_rounds, decode_rounds, bytes)
    };
    let (bat_eng, bat_prefill, bat_decode, bat_bytes) = run(true);
    let (seq_eng, seq_prefill, seq_decode, seq_bytes) = run(false);

    // (1) Transferred-payload multiset identical: every opening the
    // sequential schedule performs, exactly once each, and nothing else.
    // Projected to (from, to, class, bytes, payload) — the contextual
    // `digest` field deliberately commits to the transfer sequence
    // number, which the two schedules order differently.
    let project = |log: &[centaur::net::TransferRecord]| {
        let mut v: Vec<_> =
            log.iter().map(|r| (r.from, r.to, r.class_idx, r.bytes, r.payload)).collect();
        v.sort_unstable();
        v
    };
    let bat_log = project(bat_eng.transfer_log());
    let seq_log = project(seq_eng.transfer_log());
    assert_eq!(bat_log.len(), seq_log.len(), "batching changed the number of transfers");
    assert_eq!(bat_log, seq_log, "batching changed a transferred payload");

    // (2) P1 view census identical record for record — labels, tags,
    // shapes, and (identically seeded) the observed plaintexts themselves.
    assert!(bat_eng.leaks().is_empty(), "leaks: {:?}", bat_eng.leaks());
    assert_eq!(bat_eng.views.p1.len(), seq_eng.views.p1.len(), "census size must not change");
    let absorbs = prompt.len() + forced.len();
    assert_eq!(bat_eng.views.p1.len(), absorbs * (2 + 4 * cfg.layers));
    for (bv, sv) in bat_eng.views.p1.iter().zip(seq_eng.views.p1.iter()) {
        assert_eq!(bv.label, sv.label, "view order/labels must match the sequential path");
        assert_eq!(bv.tag, sv.tag);
        assert_eq!((bv.rows, bv.cols), (sv.rows, sv.cols));
        let (bt, st) = (bv.tensor.as_ref().unwrap(), sv.tensor.as_ref().unwrap());
        assert_eq!(bt.data(), st.data(), "view '{}' plaintext differs under batching", bv.label);
    }

    // (3) The whole point: same bytes, strictly fewer rounds, in both
    // phases (prefill steps batch identically to warm steps).
    assert_eq!(bat_bytes, seq_bytes, "batching must not change total bytes");
    assert!(
        bat_decode * 10 <= seq_decode * 6,
        "warm decode rounds must drop >=40%: {bat_decode} vs {seq_decode}"
    );
    assert!(bat_prefill < seq_prefill);
}

/// ISSUE 6 census: continuous batching shares wire *flights* across B
/// sessions, but P1's observations must stay strictly per-session — no
/// view may co-open two sessions' payloads into one tensor, every view
/// routes to exactly one session via its lane prefix, and each session's
/// census is record-for-record (label, tag, shape) the census of a solo
/// [`DecoderSession`] run — batching adds zero observations.
#[test]
fn batched_sessions_keep_per_session_censuses_disjoint_and_solo_shaped() {
    use centaur::engine::decoder::{DecodeBatch, DecoderSession};

    let cfg = ModelConfig::gpt2_tiny();
    let w = ModelWeights::random(&cfg, 0xB0);
    let prompt = [7u32, 11, 13];
    const STEPS: usize = 3;
    const B: usize = 3;
    let absorbs = prompt.len() + STEPS;
    let solo_census = absorbs * (2 + 4 * cfg.layers);

    // Solo baseline: the census structure every batched session must match.
    let mut solo_eng = CentaurEngine::with_backend(
        &cfg,
        &w,
        Box::new(NativeBackend::new()),
        EngineOptions { record_views: true, seed: 0xB1, ..Default::default() },
    )
    .unwrap();
    {
        let mut sess = DecoderSession::new(&mut solo_eng, &prompt).unwrap();
        for _ in 0..STEPS {
            sess.step_greedy().unwrap();
        }
    }
    assert_eq!(solo_eng.views.p1.len(), solo_census);

    // B sessions admitted up front, stepped to completion on one engine.
    let mut eng = CentaurEngine::with_backend(
        &cfg,
        &w,
        Box::new(NativeBackend::new()),
        EngineOptions { record_views: true, seed: 0xB2, ..Default::default() },
    )
    .unwrap();
    {
        let mut batch = DecodeBatch::new(&mut eng).unwrap();
        for _ in 0..B {
            batch.admit(&prompt, STEPS, None).unwrap();
        }
        while !batch.step().unwrap().is_empty() {}
    }
    assert!(eng.leaks().is_empty(), "leaks: {:?}", eng.leaks());
    assert_eq!(eng.views.p1.len(), B * solo_census, "batching must add zero observations");

    // 1. Shape discipline unchanged under batching: every observation is a
    //    single-token row or an (h, n_ctx) score row — never KV-cache
    //    shaped, and never a multi-row stack of several sessions' payloads.
    for v in &eng.views.p1 {
        assert!(
            (v.rows, v.cols) != (cfg.n_ctx, cfg.d),
            "view '{}' has the KV-cache shape {}x{}",
            v.label,
            v.rows,
            v.cols
        );
        assert!(v.rows == 1 || v.rows == cfg.h, "view '{}' is not a single-token row", v.label);
    }

    // 2. Every view routes to exactly one session: session 0 keeps the
    //    solo labels verbatim, session i>0 carries the "s{i} " lane prefix.
    let mut per: Vec<Vec<_>> = vec![Vec::new(); B];
    for v in &eng.views.p1 {
        let sid = match v.label.strip_prefix('s').and_then(|r| r.split_once(' ')) {
            Some((num, _)) => num.parse::<usize>().expect("lane prefix index"),
            None => 0,
        };
        assert!(sid < B, "view '{}' names an unknown session", v.label);
        per[sid].push(v);
    }

    // 3. Each session's census is record-for-record the solo census.
    for (sid, views) in per.iter().enumerate() {
        assert_eq!(views.len(), solo_census, "session {sid} census size");
        let lane_prefix = if sid == 0 { String::new() } else { format!("s{sid} ") };
        for (bv, sv) in views.iter().zip(solo_eng.views.p1.iter()) {
            let stripped = bv.label.strip_prefix(&lane_prefix).expect("lane prefix routes the view");
            assert_eq!(stripped, sv.label, "session {sid}: census order/labels diverge from solo");
            assert_eq!(bv.tag, sv.tag, "session {sid}: view '{}' retagged", bv.label);
            assert_ne!(bv.tag, PermTag::None, "view '{}' untagged", bv.label);
            assert_eq!(
                (bv.rows, bv.cols),
                (sv.rows, sv.cols),
                "session {sid}: view '{}' reshaped",
                bv.label
            );
        }
    }
}

/// ISSUE 7 census: a speculative session's P1 view census is exactly the
/// union of the solo-step censuses plus the rejected verify lanes'
/// records — a rejected lane re-absorbs its position after rollback, so
/// its `2 + 4·layers` records appear once more than in the plain session
/// — with no new label, tag, or shape class, and never a KV-cache-shaped
/// tensor. The draft conditions only on already-emitted (public) tokens,
/// so the only thing speculation adds to P1's view is *which positions
/// repeat* — the accepted-prefix lengths, public like the token count
/// itself (DESIGN.md §Speculative decode).
#[test]
fn speculative_census_is_solo_union_plus_pinned_verify_lane_records() {
    use centaur::engine::draft::Draft;
    use std::collections::HashMap;

    let cfg = ModelConfig::gpt2_tiny();
    let w = ModelWeights::random(&cfg, 0xC1);
    let prompt = [7u32, 11, 13];
    let steps = 3usize;
    let mk = || {
        CentaurEngine::with_backend(
            &cfg,
            &w,
            Box::new(NativeBackend::new()),
            EngineOptions { record_views: true, seed: 0xC2, ..Default::default() },
        )
        .unwrap()
    };

    // Plain solo baseline: prompt + one absorb per emitted token.
    let mut plain_eng = mk();
    plain_eng.generate(&prompt, steps).unwrap();
    let per_absorb = 2 + 4 * cfg.layers;
    assert_eq!(plain_eng.views.p1.len(), (prompt.len() + steps) * per_absorb);

    // Speculative worst case, k=2 with the always-rejected draft: the
    // verify steps absorb positions (3,4), (4,5), (5) — the rejected
    // lanes re-open pos 4 and pos 5 once each after their rollback.
    let mut spec_eng = mk();
    let (out, spec) = spec_eng.generate_speculative(&prompt, steps, &Draft::Adversarial, 2).unwrap();
    assert_eq!(out.tokens.len(), steps);
    assert_eq!(spec.accepted, 0);
    assert_eq!(spec.verify_steps, steps as u64);
    assert!(spec_eng.leaks().is_empty(), "leaks: {:?}", spec_eng.leaks());
    assert_eq!(spec_eng.views.p1.len(), (prompt.len() + 5) * per_absorb);

    // Shape/tag discipline unchanged by speculation: no KV-cache-shaped
    // observation, single-token rows only, every record π-tagged and
    // structurally identical to the solo record of the same label.
    let plain_shapes: HashMap<&str, _> = plain_eng
        .views
        .p1
        .iter()
        .map(|v| (v.label.as_str(), (v.tag, v.rows, v.cols)))
        .collect();
    for v in &spec_eng.views.p1 {
        assert!(
            (v.rows, v.cols) != (cfg.n_ctx, cfg.d),
            "view '{}' has the KV-cache shape {}x{}",
            v.label,
            v.rows,
            v.cols
        );
        assert!(v.rows == 1 || v.rows == cfg.h, "view '{}' is not a single-token row", v.label);
        assert_ne!(v.tag, PermTag::None, "view '{}' untagged", v.label);
        let &(tag, rows, cols) = plain_shapes
            .get(v.label.as_str())
            .unwrap_or_else(|| panic!("view '{}' is not in any solo-step census", v.label));
        assert_eq!((v.tag, v.rows, v.cols), (tag, rows, cols), "view '{}' reclassified", v.label);
    }

    // Census arithmetic: the speculative multiset is the solo multiset
    // plus exactly the two rejected lanes' per-absorb records.
    let census = |eng: &CentaurEngine| {
        let mut m: HashMap<String, usize> = HashMap::new();
        for v in &eng.views.p1 {
            *m.entry(v.label.clone()).or_default() += 1;
        }
        m
    };
    let (plain_census, spec_census) = (census(&plain_eng), census(&spec_eng));
    let mut extra = 0usize;
    for (label, &n) in &spec_census {
        let base = plain_census.get(label).copied().unwrap_or(0);
        assert!(n == base || n == base + 1, "view '{label}' repeated beyond one rejected lane");
        if n == base + 1 {
            assert!(
                label.contains("pos4") || label.contains("pos5"),
                "extra record '{label}' is not a rejected verify lane"
            );
            extra += 1;
        }
    }
    assert_eq!(extra, 2 * per_absorb, "exactly the two rejected lanes' records are extra");
    for (label, &n) in &plain_census {
        assert!(
            spec_census.get(label).copied().unwrap_or(0) >= n,
            "solo view '{label}' missing from the speculative census"
        );
    }
}

#[test]
fn permonly_leak_detector_fires() {
    let cfg = ModelConfig::gpt2_tiny();
    let w = ModelWeights::random(&cfg, 51);
    let mut eng = PermOnlyEngine::new(&cfg, &w, NetworkProfile::lan(), true);
    eng.infer(&toks(&cfg, 52)).unwrap();
    let leaks = eng.views.leaks();
    assert_eq!(leaks.len(), 4 * cfg.layers);
    assert!(leaks.iter().any(|l| l.contains("O1")));
}

#[test]
fn shares_sent_to_servers_look_uniform() {
    // χ²-lite: the low 8 bits of P1's input share of a *constant* tensor
    // should be close to uniform — the masking property of sharing.
    let cfg = ModelConfig::bert_tiny();
    let mut mpc = centaur::mpc::Mpc::new(
        centaur::net::NetSim::new(NetworkProfile::lan()),
        61,
    );
    let x = centaur::tensor::RingTensor::from_vec(64, 64, vec![centaur::fixed::encode(1.0); 64 * 64]);
    let sh = mpc.share_local(&x);
    let mut counts = [0usize; 256];
    for &v in sh.s0.data() {
        counts[(v as u8) as usize] += 1;
    }
    let expected = (64.0 * 64.0) / 256.0;
    let chi2: f64 = counts.iter().map(|&c| {
        let d = c as f64 - expected;
        d * d / expected
    }).sum();
    // df=255; mean 255, sd ~22.6 — allow generous slack
    assert!(chi2 < 400.0, "share bytes not uniform enough: chi2={chi2}");
    let _ = cfg;
}

#[test]
fn permutation_security_bits_scale() {
    // §2.3: d=1280 → ~2^11372 permutations; even tiny d=64 gives ~2^296.
    assert!(centaur::perm::Perm::security_bits(64) > 250.0);
    assert!(centaur::perm::Perm::security_bits(768) > 6000.0);
    assert!(centaur::perm::Perm::security_bits(1280) > 11000.0);
}
