//! Property tests pinning every host-selectable ring kernel against the
//! naive reference, bit-for-bit (ISSUE 9).
//!
//! Wrapping addition in `Z_{2^64}` is associative and commutative, so a
//! SIMD kernel that reorders the summation still produces the identical
//! ring element — these tests enforce that across degenerate and
//! lane-width ± 1 shapes on every kernel the host can run, plus the
//! forced-scalar dispatch path CI exercises via `CENTAUR_RING_KERNEL`.

use centaur::ring;
use centaur::runtime::kernel;
use centaur::runtime::RingKernel;
use centaur::tensor::RingTensor;
use centaur::util::rng::Rng;

fn rt(r: usize, c: usize, rng: &mut Rng) -> RingTensor {
    RingTensor::from_vec(r, c, rng.vec_i64(r * c))
}

/// Every kernel the host/build can actually run, except `xla` (needs
/// artifacts + PJRT; covered by the artifact smoke, not unit parity).
fn host_kernels() -> Vec<&'static dyn RingKernel> {
    kernel::available_kernels()
        .iter()
        .filter(|d| d.available && d.name != "xla")
        .map(|d| kernel::kernel_by_name(d.name).unwrap())
        .collect()
}

/// m/k/n grid around the SIMD lane widths (2, 4, 8) and the 4-column
/// register block: 0, 1, lane ± 1, block ± 1, and non-multiples.
const AWKWARD: &[usize] = &[0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17];

#[test]
fn all_kernels_match_naive_on_awkward_shapes() {
    let kernels = host_kernels();
    assert!(!kernels.is_empty(), "scalar must always be available");
    let mut rng = Rng::new(0x5EED_0009);
    for &m in AWKWARD {
        for &k in AWKWARD {
            for &n in AWKWARD {
                let a = rt(m, k, &mut rng);
                let b = rt(k, n, &mut rng);
                let want = ring::matmul_naive(&a, &b);
                let bt = b.transpose();
                for kern in &kernels {
                    assert_eq!(
                        kern.matmul_nt(&a, &bt),
                        want,
                        "kernel {} diverged at m={m} k={k} n={n}",
                        kern.name()
                    );
                }
            }
        }
    }
}

#[test]
fn all_kernels_match_naive_on_larger_odd_shapes() {
    let kernels = host_kernels();
    let mut rng = Rng::new(0xDEC0DE);
    // Odd, non-power-of-two shapes large enough to cross the 4-column
    // block and every lane width many times, plus extreme-value operands
    // that make any non-wrapping accumulation overflow visibly.
    for (m, k, n) in [(64, 257, 129), (33, 1023, 65), (5, 4099, 3)] {
        let a = rt(m, k, &mut rng);
        let mut b = rt(k, n, &mut rng);
        b.data_mut()[0] = i64::MAX;
        b.data_mut()[k * n - 1] = i64::MIN;
        let want = ring::matmul_naive(&a, &b);
        let bt = b.transpose();
        for kern in &kernels {
            assert_eq!(kern.matmul_nt(&a, &bt), want, "kernel {} at {m}x{k}x{n}", kern.name());
        }
    }
}

#[test]
fn all_kernels_match_scalar_dot() {
    let kernels = host_kernels();
    let mut rng = Rng::new(0xD07);
    for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 16, 31, 64, 257, 1000] {
        let x = rng.vec_i64(len);
        let y = rng.vec_i64(len);
        let want = ring::dot_wrapping(&x, &y);
        for kern in &kernels {
            assert_eq!(kern.dot(&x, &y), want, "kernel {} dot at len {len}", kern.name());
        }
    }
}

#[test]
fn dispatched_matmul_matches_naive() {
    // Whatever kernel this host/env resolves to (including the CI leg that
    // forces CENTAUR_RING_KERNEL=scalar), the public ring::matmul must
    // agree with the reference.
    let mut rng = Rng::new(0xABCD);
    let a = rt(13, 37, &mut rng);
    let b = rt(37, 11, &mut rng);
    assert_eq!(ring::matmul(&a, &b), ring::matmul_naive(&a, &b));
    assert!(
        kernel::KERNEL_NAMES.contains(&kernel::selected_name()),
        "dispatch resolved to an unregistered kernel"
    );
}
