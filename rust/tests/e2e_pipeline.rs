//! End-to-end pipeline tests. The artifact-driven checks are gated (they
//! skip with a notice when `make artifacts` has not run); the incremental
//! decode parity suite below runs everywhere on random weights.

use centaur::coordinator::{Coordinator, ServerConfig};
use centaur::data::{artifacts_dir, AttackCorpora, LmData, TaskData, Vocab};
use centaur::model::{plaintext, ModelConfig, ModelWeights, Variant};
use centaur::report::metrics;

fn ready() -> bool {
    let ok = std::path::Path::new("artifacts/data/vocab.json").exists()
        && std::path::Path::new("artifacts/weights/bert-tiny-qnli/manifest.json").exists();
    if !ok {
        eprintln!("skipping e2e test: run `make artifacts` first");
    }
    ok
}

#[test]
fn trained_checkpoint_beats_chance_via_rust_forward() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    for task in ["qnli", "mrpc", "cola"] {
        let td = TaskData::load(&dir, task).unwrap();
        let (cfg, w) = ModelWeights::load_tag(&dir, &format!("bert-tiny-{task}")).unwrap();
        let preds = metrics::predict(&cfg, &w, &td.test, Variant::Exact);
        let acc = metrics::accuracy(&preds, &td.test.labels);
        assert!(acc > 62.0, "{task}: rust-forward accuracy {acc:.1}% too close to chance");
    }
}

#[test]
fn trained_lm_perplexity_reasonable() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    let lm = LmData::load(&dir, "wikitext2").unwrap();
    let (cfg, w) = ModelWeights::load_tag(&dir, "gpt2-tiny-wikitext2").unwrap();
    let test: Vec<Vec<u32>> = lm.test.iter().take(40).cloned().collect();
    let ppl = metrics::perplexity(&cfg, &w, &test, Variant::Exact);
    // untrained would be near vocab size (≈460); trained should be far lower
    assert!(ppl < 60.0, "perplexity {ppl:.1} suggests the checkpoint didn't load correctly");
}

#[test]
fn served_accuracy_matches_offline_forward() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    let td = TaskData::load(&dir, "qnli").unwrap();
    let (cfg, w) = ModelWeights::load_tag(&dir, "bert-tiny-qnli").unwrap();
    let n = 10usize;
    // offline plaintext predictions
    let sub = centaur::data::Split {
        ids: td.test.ids.iter().take(n).cloned().collect(),
        labels: td.test.labels.iter().take(n).copied().collect(),
    };
    let offline = metrics::predict(&cfg, &w, &sub, Variant::Exact);
    // served through the coordinator (full Centaur protocol)
    let sc = ServerConfig::new(cfg.clone(), w);
    let coord = Coordinator::start(sc).unwrap();
    let rxs: Vec<_> = sub.ids.iter().map(|ids| coord.submit(ids.clone())).collect();
    for (rx, off) in rxs.into_iter().zip(&offline) {
        let resp = rx.recv().unwrap().unwrap();
        let am = |v: &[f32]| {
            v.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap()
        };
        assert_eq!(am(&resp.logits), am(off), "served argmax differs from plaintext");
    }
    coord.shutdown();
}

#[test]
fn attack_corpora_and_vocab_consistent() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    let vocab = Vocab::load(&dir).unwrap();
    let corp = AttackCorpora::load(&dir).unwrap();
    assert!(corp.private.len() >= 50);
    assert!(corp.aux.len() >= 500);
    for s in corp.private.iter().take(10) {
        assert_eq!(s.len(), corp.seq_len);
        assert!(s.iter().all(|&t| (t as usize) < vocab.len()));
        let text = vocab.decode(s);
        assert!(text.split(' ').count() >= 5, "private sentence too short: {text}");
    }
}

/// Fixed-point noise on tiny-model logits is ~1e-3; 0.03 is 30x that.
const DECODE_MARGIN: f32 = 0.03;

/// Margin-gated plaintext greedy rollout shared by the decode parity
/// tests: `(token, decisive)` per generated step, where a step is
/// *decisive* when its top-2 regular-token margin exceeds the fixed-point
/// noise bound — only decisive argmaxes are numerically meaningful to
/// compare against the protocol paths.
fn margin_gated_rollout(
    cfg: &ModelConfig,
    w: &ModelWeights,
    prompt: &[u32],
    steps: usize,
) -> Vec<(u32, bool)> {
    use centaur::data::{greedy_regular_token, NUM_SPECIAL_TOKENS};
    let mut seq = prompt.to_vec();
    let mut expected = Vec::with_capacity(steps);
    for _ in 0..steps {
        let mut padded = seq.clone();
        padded.resize(cfg.n_ctx, 0);
        let logits = plaintext::forward(cfg, w, &padded, Variant::Exact);
        let row = logits.row(seq.len() - 1);
        let tok = greedy_regular_token(row);
        let (mut best, mut second) = (f32::NEG_INFINITY, f32::NEG_INFINITY);
        for &v in row.iter().skip(NUM_SPECIAL_TOKENS) {
            if v > best {
                second = best;
                best = v;
            } else if v > second {
                second = v;
            }
        }
        expected.push((tok, best - second >= DECODE_MARGIN));
        seq.push(tok);
    }
    expected
}

/// Decode parity (no artifacts needed): the *correlated* incremental
/// KV-cache path, the PR 2 plain per-step path, the full-recompute path,
/// and the plaintext greedy reference must emit the same token at every
/// step, across every network profile and several seeds. The comparison is
/// teacher-forced on the plaintext rollout so a single step can be judged
/// in isolation, and a step is only asserted when its plaintext top-2
/// margin exceeds the fixed-point noise bound (see [`margin_gated_rollout`]).
#[test]
fn incremental_decode_parity_across_profiles_and_seeds() {
    use centaur::data::{greedy_regular_token, NUM_SPECIAL_TOKENS};
    use centaur::engine::decoder::DecoderSession;
    use centaur::engine::{CentaurEngine, EngineOptions};
    use centaur::net::NetworkProfile;
    use centaur::runtime::NativeBackend;
    use centaur::util::prop::check;

    const STEPS: usize = 3;

    check("correlated == plain steps == full recompute == plaintext greedy", 3, |g| {
        let cfg = ModelConfig::gpt2_tiny();
        let seed = 0xD3C0DE ^ (g.case as u64).wrapping_mul(7919);
        let w = ModelWeights::random(&cfg, seed);
        let prompt: Vec<u32> =
            (0..3).map(|_| (g.below(cfg.vocab - NUM_SPECIAL_TOKENS) + NUM_SPECIAL_TOKENS) as u32).collect();

        // Plaintext greedy rollout + per-step decisiveness.
        let expected = margin_gated_rollout(&cfg, &w, &prompt, STEPS);
        let mut seq = prompt.clone();
        seq.extend(expected.iter().map(|&(tok, _)| tok));
        assert_eq!(seq.len(), prompt.len() + STEPS);

        for name in NetworkProfile::ALL_NAMES {
            let profile = NetworkProfile::by_name(name).unwrap();
            let mk = |decode_correlations: bool, seed: u64| {
                CentaurEngine::with_backend(
                    &cfg,
                    &w,
                    Box::new(NativeBackend::new()),
                    EngineOptions { profile, seed, decode_correlations, ..Default::default() },
                )
                .unwrap()
            };
            let mut e_corr = mk(true, seed ^ 0xA);
            let mut e_plain = mk(false, seed ^ 0xC);
            let mut e_full = CentaurEngine::new(&cfg, &w, profile, seed ^ 0xB).unwrap();
            let corr_bytes;
            let plain_bytes;
            let mut full_bytes = 0u64;
            {
                let mut sess_corr = DecoderSession::new(&mut e_corr, &prompt).unwrap();
                let mut sess_plain = DecoderSession::new(&mut e_plain, &prompt).unwrap();
                for (s, &(want, decisive)) in expected.iter().enumerate() {
                    let corr_tok = greedy_regular_token(sess_corr.logits().row(0));
                    let plain_tok = greedy_regular_token(sess_plain.logits().row(0));
                    let prefix_len = prompt.len() + s;
                    let mut padded = seq[..prefix_len].to_vec();
                    padded.resize(cfg.n_ctx, 0);
                    let full_out = e_full.infer(&padded).unwrap();
                    let full_tok = greedy_regular_token(full_out.logits.row(prefix_len - 1));
                    full_bytes += full_out.stats.bytes_total();
                    if decisive {
                        assert_eq!(corr_tok, want, "correlated != plaintext at step {s} ({name})");
                        assert_eq!(plain_tok, want, "plain steps != plaintext at step {s} ({name})");
                        assert_eq!(full_tok, want, "full recompute != plaintext at step {s} ({name})");
                    }
                    // Teacher-force the plaintext token into both sessions.
                    sess_corr.absorb(want).unwrap();
                    sess_plain.absorb(want).unwrap();
                }
                corr_bytes = sess_corr.total_cost().bytes_total();
                plain_bytes = sess_plain.total_cost().bytes_total();
            }
            assert!(e_corr.leaks().is_empty(), "correlated session leaked ({name})");
            assert!(e_plain.leaks().is_empty(), "plain session leaked ({name})");
            assert!(
                plain_bytes > corr_bytes,
                "correlations must move fewer total bytes even including setup ({name}): \
                 {plain_bytes} vs {corr_bytes}"
            );
            assert!(
                full_bytes > plain_bytes,
                "incremental must move fewer bytes than recompute ({name}): {full_bytes} vs {plain_bytes}"
            );
        }
    });
}

/// Cold start: a serving pool with **no correlations stocked** must not
/// break decode — the dealer falls back to generating the bundles on
/// demand (pool misses recorded, session still token-exact), and a
/// correlations-off engine falls back to plain per-step triples.
#[test]
fn cold_start_pool_without_correlations_falls_back() {
    use centaur::data::greedy_regular_token;
    use centaur::engine::decoder::DecoderSession;
    use centaur::engine::{CentaurEngine, EngineOptions};
    use centaur::mpc::TriplePool;
    use centaur::net::NetworkProfile;
    use centaur::runtime::NativeBackend;
    use std::sync::Arc;

    let cfg = ModelConfig::gpt2_tiny();
    let w = ModelWeights::random(&cfg, 0xC01D);
    let prompt: Vec<u32> = vec![7, 11, 13];
    let steps = 2usize;

    let expected = margin_gated_rollout(&cfg, &w, &prompt, steps);

    let run = |decode_correlations: bool, pool: Option<Arc<TriplePool>>, seed: u64| {
        let mut eng = CentaurEngine::with_backend(
            &cfg,
            &w,
            Box::new(NativeBackend::new()),
            EngineOptions {
                profile: NetworkProfile::lan(),
                seed,
                triple_pool: pool,
                decode_correlations,
                ..Default::default()
            },
        )
        .unwrap();
        let mut sess = DecoderSession::new(&mut eng, &prompt).unwrap();
        for (s, &(want, decisive)) in expected.iter().enumerate() {
            let tok = greedy_regular_token(sess.logits().row(0));
            if decisive {
                assert_eq!(tok, want, "step {s} (correlations={decode_correlations})");
            }
            sess.absorb(want).unwrap();
        }
    };

    // 1. Correlations on, attached pool empty: every bundle is a miss,
    //    generated on demand — the session still works, token-exact.
    let pool = Arc::new(TriplePool::new(0xC01D ^ 1, 1));
    run(true, Some(Arc::clone(&pool)), 0xC01D ^ 2);
    assert!(pool.misses() > 0, "empty pool must record the correlation misses");
    assert_eq!(pool.hits(), 0);

    // 2. Correlations disabled entirely: the dealer serves plain per-step
    //    triples (the PR 2 path) and the tokens still match.
    run(false, None, 0xC01D ^ 3);
}

#[test]
fn variant_checkpoints_differ_from_exact() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    let (_c1, w_exact) = ModelWeights::load_tag(&dir, "bert-tiny-qnli").unwrap();
    let (_c2, w_mpcf) = ModelWeights::load_tag(&dir, "bert-tiny-qnli-mpcformer").unwrap();
    // fine-tuning moved the weights
    assert!(w_exact.emb_word.max_abs_diff(&w_mpcf.emb_word) > 1e-5);
}
