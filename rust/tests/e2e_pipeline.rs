//! End-to-end pipeline tests. The artifact-driven checks are gated (they
//! skip with a notice when `make artifacts` has not run); the incremental
//! decode parity suite below runs everywhere on random weights.

use centaur::coordinator::{Coordinator, ServerConfig};
use centaur::data::{artifacts_dir, AttackCorpora, LmData, TaskData, Vocab};
use centaur::model::{plaintext, ModelConfig, ModelWeights, Variant};
use centaur::report::metrics;

fn ready() -> bool {
    let ok = std::path::Path::new("artifacts/data/vocab.json").exists()
        && std::path::Path::new("artifacts/weights/bert-tiny-qnli/manifest.json").exists();
    if !ok {
        eprintln!("skipping e2e test: run `make artifacts` first");
    }
    ok
}

#[test]
fn trained_checkpoint_beats_chance_via_rust_forward() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    for task in ["qnli", "mrpc", "cola"] {
        let td = TaskData::load(&dir, task).unwrap();
        let (cfg, w) = ModelWeights::load_tag(&dir, &format!("bert-tiny-{task}")).unwrap();
        let preds = metrics::predict(&cfg, &w, &td.test, Variant::Exact);
        let acc = metrics::accuracy(&preds, &td.test.labels);
        assert!(acc > 62.0, "{task}: rust-forward accuracy {acc:.1}% too close to chance");
    }
}

#[test]
fn trained_lm_perplexity_reasonable() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    let lm = LmData::load(&dir, "wikitext2").unwrap();
    let (cfg, w) = ModelWeights::load_tag(&dir, "gpt2-tiny-wikitext2").unwrap();
    let test: Vec<Vec<u32>> = lm.test.iter().take(40).cloned().collect();
    let ppl = metrics::perplexity(&cfg, &w, &test, Variant::Exact);
    // untrained would be near vocab size (≈460); trained should be far lower
    assert!(ppl < 60.0, "perplexity {ppl:.1} suggests the checkpoint didn't load correctly");
}

#[test]
fn served_accuracy_matches_offline_forward() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    let td = TaskData::load(&dir, "qnli").unwrap();
    let (cfg, w) = ModelWeights::load_tag(&dir, "bert-tiny-qnli").unwrap();
    let n = 10usize;
    // offline plaintext predictions
    let sub = centaur::data::Split {
        ids: td.test.ids.iter().take(n).cloned().collect(),
        labels: td.test.labels.iter().take(n).copied().collect(),
    };
    let offline = metrics::predict(&cfg, &w, &sub, Variant::Exact);
    // served through the coordinator (full Centaur protocol)
    let sc = ServerConfig::new(cfg.clone(), w);
    let coord = Coordinator::start(sc).unwrap();
    let rxs: Vec<_> = sub.ids.iter().map(|ids| coord.submit(ids.clone())).collect();
    for (rx, off) in rxs.into_iter().zip(&offline) {
        let resp = rx.recv().unwrap().unwrap();
        let am = |v: &[f32]| {
            v.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap()
        };
        assert_eq!(am(&resp.logits), am(off), "served argmax differs from plaintext");
    }
    coord.shutdown();
}

#[test]
fn attack_corpora_and_vocab_consistent() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    let vocab = Vocab::load(&dir).unwrap();
    let corp = AttackCorpora::load(&dir).unwrap();
    assert!(corp.private.len() >= 50);
    assert!(corp.aux.len() >= 500);
    for s in corp.private.iter().take(10) {
        assert_eq!(s.len(), corp.seq_len);
        assert!(s.iter().all(|&t| (t as usize) < vocab.len()));
        let text = vocab.decode(s);
        assert!(text.split(' ').count() >= 5, "private sentence too short: {text}");
    }
}

/// Decode parity (no artifacts needed): the incremental KV-cache path, the
/// full-recompute path, and the plaintext greedy reference must emit the
/// same token at every step, across every network profile and several
/// seeds. The comparison is teacher-forced on the plaintext rollout so a
/// single step can be judged in isolation, and a step is only asserted
/// when its plaintext top-2 margin exceeds the fixed-point noise bound
/// (non-decisive argmaxes are numerically meaningless to compare; margins
/// are almost always far above the bound).
#[test]
fn incremental_decode_parity_across_profiles_and_seeds() {
    use centaur::data::{greedy_regular_token, NUM_SPECIAL_TOKENS};
    use centaur::engine::decoder::DecoderSession;
    use centaur::engine::CentaurEngine;
    use centaur::net::NetworkProfile;
    use centaur::util::prop::check;

    const STEPS: usize = 3;
    // Fixed-point noise on tiny-model logits is ~1e-3; 0.03 is 30x that.
    const MARGIN: f32 = 0.03;

    check("incremental == full recompute == plaintext greedy", 3, |g| {
        let cfg = ModelConfig::gpt2_tiny();
        let seed = 0xD3C0DE ^ (g.case as u64).wrapping_mul(7919);
        let w = ModelWeights::random(&cfg, seed);
        let prompt: Vec<u32> =
            (0..3).map(|_| (g.below(cfg.vocab - NUM_SPECIAL_TOKENS) + NUM_SPECIAL_TOKENS) as u32).collect();

        // Plaintext greedy rollout + per-step decisiveness.
        let mut seq = prompt.clone();
        let mut expected: Vec<(u32, bool)> = Vec::new();
        for _ in 0..STEPS {
            let mut padded = seq.clone();
            padded.resize(cfg.n_ctx, 0);
            let logits = plaintext::forward(&cfg, &w, &padded, Variant::Exact);
            let row = logits.row(seq.len() - 1);
            let tok = greedy_regular_token(row);
            let (mut best, mut second) = (f32::NEG_INFINITY, f32::NEG_INFINITY);
            for &v in row.iter().skip(NUM_SPECIAL_TOKENS) {
                if v > best {
                    second = best;
                    best = v;
                } else if v > second {
                    second = v;
                }
            }
            expected.push((tok, best - second >= MARGIN));
            seq.push(tok);
        }
        assert_eq!(seq.len(), prompt.len() + STEPS);

        for name in NetworkProfile::ALL_NAMES {
            let profile = NetworkProfile::by_name(name).unwrap();
            let mut e_inc = CentaurEngine::new(&cfg, &w, profile, seed ^ 0xA).unwrap();
            let mut e_full = CentaurEngine::new(&cfg, &w, profile, seed ^ 0xB).unwrap();
            let inc_bytes;
            let mut full_bytes = 0u64;
            {
                let mut sess = DecoderSession::new(&mut e_inc, &prompt).unwrap();
                for (s, &(want, decisive)) in expected.iter().enumerate() {
                    let inc_tok = greedy_regular_token(sess.logits().row(0));
                    let prefix_len = prompt.len() + s;
                    let mut padded = seq[..prefix_len].to_vec();
                    padded.resize(cfg.n_ctx, 0);
                    let full_out = e_full.infer(&padded).unwrap();
                    let full_tok = greedy_regular_token(full_out.logits.row(prefix_len - 1));
                    full_bytes += full_out.stats.bytes_total();
                    if decisive {
                        assert_eq!(inc_tok, want, "incremental != plaintext at step {s} ({name})");
                        assert_eq!(full_tok, want, "full recompute != plaintext at step {s} ({name})");
                    }
                    // Teacher-force the plaintext token into the session.
                    sess.absorb(want).unwrap();
                }
                inc_bytes = sess.total_cost().bytes_total();
            }
            assert!(e_inc.leaks().is_empty(), "decode session leaked ({name})");
            assert!(
                full_bytes > inc_bytes,
                "incremental must move fewer bytes ({name}): {full_bytes} vs {inc_bytes}"
            );
        }
    });
}

#[test]
fn variant_checkpoints_differ_from_exact() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    let (_c1, w_exact) = ModelWeights::load_tag(&dir, "bert-tiny-qnli").unwrap();
    let (_c2, w_mpcf) = ModelWeights::load_tag(&dir, "bert-tiny-qnli-mpcformer").unwrap();
    // fine-tuning moved the weights
    assert!(w_exact.emb_word.max_abs_diff(&w_mpcf.emb_word) > 1e-5);
}
