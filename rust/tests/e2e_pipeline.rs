//! End-to-end pipeline tests over the build artifacts (gated: they skip
//! with a notice when `make artifacts` has not run).

use centaur::coordinator::{Coordinator, ServerConfig};
use centaur::data::{artifacts_dir, AttackCorpora, LmData, TaskData, Vocab};
use centaur::model::{ModelWeights, Variant};
use centaur::report::metrics;

fn ready() -> bool {
    let ok = std::path::Path::new("artifacts/data/vocab.json").exists()
        && std::path::Path::new("artifacts/weights/bert-tiny-qnli/manifest.json").exists();
    if !ok {
        eprintln!("skipping e2e test: run `make artifacts` first");
    }
    ok
}

#[test]
fn trained_checkpoint_beats_chance_via_rust_forward() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    for task in ["qnli", "mrpc", "cola"] {
        let td = TaskData::load(&dir, task).unwrap();
        let (cfg, w) = ModelWeights::load_tag(&dir, &format!("bert-tiny-{task}")).unwrap();
        let preds = metrics::predict(&cfg, &w, &td.test, Variant::Exact);
        let acc = metrics::accuracy(&preds, &td.test.labels);
        assert!(acc > 62.0, "{task}: rust-forward accuracy {acc:.1}% too close to chance");
    }
}

#[test]
fn trained_lm_perplexity_reasonable() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    let lm = LmData::load(&dir, "wikitext2").unwrap();
    let (cfg, w) = ModelWeights::load_tag(&dir, "gpt2-tiny-wikitext2").unwrap();
    let test: Vec<Vec<u32>> = lm.test.iter().take(40).cloned().collect();
    let ppl = metrics::perplexity(&cfg, &w, &test, Variant::Exact);
    // untrained would be near vocab size (≈460); trained should be far lower
    assert!(ppl < 60.0, "perplexity {ppl:.1} suggests the checkpoint didn't load correctly");
}

#[test]
fn served_accuracy_matches_offline_forward() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    let td = TaskData::load(&dir, "qnli").unwrap();
    let (cfg, w) = ModelWeights::load_tag(&dir, "bert-tiny-qnli").unwrap();
    let n = 10usize;
    // offline plaintext predictions
    let sub = centaur::data::Split {
        ids: td.test.ids.iter().take(n).cloned().collect(),
        labels: td.test.labels.iter().take(n).copied().collect(),
    };
    let offline = metrics::predict(&cfg, &w, &sub, Variant::Exact);
    // served through the coordinator (full Centaur protocol)
    let sc = ServerConfig::new(cfg.clone(), w);
    let coord = Coordinator::start(sc).unwrap();
    let rxs: Vec<_> = sub.ids.iter().map(|ids| coord.submit(ids.clone())).collect();
    for (rx, off) in rxs.into_iter().zip(&offline) {
        let resp = rx.recv().unwrap().unwrap();
        let am = |v: &[f32]| {
            v.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap()
        };
        assert_eq!(am(&resp.logits), am(off), "served argmax differs from plaintext");
    }
    coord.shutdown();
}

#[test]
fn attack_corpora_and_vocab_consistent() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    let vocab = Vocab::load(&dir).unwrap();
    let corp = AttackCorpora::load(&dir).unwrap();
    assert!(corp.private.len() >= 50);
    assert!(corp.aux.len() >= 500);
    for s in corp.private.iter().take(10) {
        assert_eq!(s.len(), corp.seq_len);
        assert!(s.iter().all(|&t| (t as usize) < vocab.len()));
        let text = vocab.decode(s);
        assert!(text.split(' ').count() >= 5, "private sentence too short: {text}");
    }
}

#[test]
fn variant_checkpoints_differ_from_exact() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    let (_c1, w_exact) = ModelWeights::load_tag(&dir, "bert-tiny-qnli").unwrap();
    let (_c2, w_mpcf) = ModelWeights::load_tag(&dir, "bert-tiny-qnli-mpcformer").unwrap();
    // fine-tuning moved the weights
    assert!(w_exact.emb_word.max_abs_diff(&w_mpcf.emb_word) > 1e-5);
}
