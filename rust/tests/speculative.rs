//! Speculative-decode suite (DESIGN.md §Speculative decode).
//!
//! Two properties carry the whole feature:
//!
//! 1. **Greedy parity** — the emitted stream is token-for-token what plain
//!    incremental greedy decode produces, for every draft source, every
//!    `spec_k`, and every network profile. The accept rule only ever keeps
//!    draft tokens the private model's own greedy choice agrees with, so
//!    speculation changes *when* tokens are computed, never *which*.
//!    Weight seeds are screened for a fully decisive plaintext rollout
//!    (top-1/top-2 logit margin ≥ 30× the fixed-point noise) so the pins
//!    are exact token equalities, not margin-gated comparisons.
//! 2. **Rollback exactness** — rejecting speculative rows must leave the
//!    session in the share-for-share state of a twin that never appended
//!    them: cache digests, correlation `uses_left`, opening counters, and
//!    every subsequent step's output shares are bit-identical, and the
//!    `TriplePool` demand a speculative session registered balances to
//!    zero when eviction hands the unconsumed lane demand back.

use centaur::data::{greedy_regular_token, NUM_SPECIAL_TOKENS};
use centaur::engine::draft::Draft;
use centaur::engine::views::Views;
use centaur::engine::{CentaurEngine, EngineOptions};
use centaur::fixed;
use centaur::model::{plaintext, ModelConfig, ModelWeights, PermSet, PermutedModel, Variant};
use centaur::mpc::{Mpc, Share, TriplePool, TripleShape};
use centaur::net::{NetSim, NetworkProfile, OpClass};
use centaur::protocols::layer::{
    self, deal_kv_correlations, transformer_layer_step, LayerKvCache, ProtoCtx,
};
use centaur::protocols::ppp;
use centaur::runtime::NativeBackend;
use centaur::tensor::FloatTensor;
use centaur::util::prop::check;
use centaur::util::rng::Rng;

/// Fixed-point noise on tiny-model logits is ~1e-3; 30× that margin makes
/// every protocol run (plain, speculative, rolled-back re-steps — each a
/// different noise realization) resolve the same argmax as plaintext, so
/// the parity assertions below are exact, not margin-gated.
const DECISIVE_MARGIN: f32 = 0.03;

fn mk_engine(cfg: &ModelConfig, w: &ModelWeights, profile: NetworkProfile, seed: u64) -> CentaurEngine {
    CentaurEngine::with_backend(
        cfg,
        w,
        Box::new(NativeBackend::new()),
        EngineOptions { profile, seed, ..Default::default() },
    )
    .unwrap()
}

/// Search weight seeds from `base` for one whose plaintext greedy rollout
/// is decisive at every step; returns the weights and the pinned rollout.
/// Deterministic: the same `base` always lands on the same seed.
fn decisive_weights(cfg: &ModelConfig, prompt: &[u32], steps: usize, base: u64) -> (ModelWeights, Vec<u32>) {
    'seed: for off in 0..64u64 {
        let w = ModelWeights::random(cfg, base + off);
        let mut seq = prompt.to_vec();
        let mut toks = Vec::with_capacity(steps);
        for _ in 0..steps {
            let mut padded = seq.clone();
            padded.resize(cfg.n_ctx, 0);
            let logits = plaintext::forward(cfg, &w, &padded, Variant::Exact);
            let row = logits.row(seq.len() - 1);
            let (mut best, mut second) = (f32::NEG_INFINITY, f32::NEG_INFINITY);
            for &v in row.iter().skip(NUM_SPECIAL_TOKENS) {
                if v > best {
                    second = best;
                    best = v;
                } else if v > second {
                    second = v;
                }
            }
            if best - second < DECISIVE_MARGIN {
                continue 'seed;
            }
            let tok = greedy_regular_token(row);
            toks.push(tok);
            seq.push(tok);
        }
        return (w, toks);
    }
    panic!("no weight seed with a fully decisive {steps}-step rollout in {base}..{}", base + 64);
}

/// The tentpole pin: across 3 decisive weight draws × {lan, wan3} ×
/// k ∈ {1, 2, 4, 8} × both serving draft sources, the speculative stream
/// equals the plain incremental greedy stream token for token — including
/// the degenerate k=1 schedule, which must also charge the plain path's
/// exact decode ledger (it runs the identical single-lane flights).
#[test]
fn speculative_stream_is_token_identical_to_plain_greedy() {
    let cfg = ModelConfig::gpt2_tiny();
    let prompt: Vec<u32> = vec![7, 11, 13, 17];
    let steps = 5usize;
    for base in [300u64, 400, 500] {
        let (w, rollout) = decisive_weights(&cfg, &prompt, steps, base);
        let mut plain_e = mk_engine(&cfg, &w, NetworkProfile::lan(), base ^ 0xA);
        let plain = plain_e.generate_streaming(&prompt, steps, &mut |_, _, _| true).unwrap();
        assert_eq!(plain.tokens, rollout, "decisive rollout must pin the plain protocol stream");
        assert!(plain_e.leaks().is_empty());

        for pname in ["lan", "wan3"] {
            let profile = NetworkProfile::by_name(pname).unwrap();
            for k in [1usize, 2, 4, 8] {
                for draft in [Draft::tiny(&cfg, &w), Draft::Ngram] {
                    let mut e = mk_engine(&cfg, &w, profile, base ^ 0xA);
                    let (out, spec) = e.generate_speculative(&prompt, steps, &draft, k).unwrap();
                    assert_eq!(
                        out.tokens,
                        plain.tokens,
                        "weights {base}/{pname}/k={k}/{}: speculative stream diverged from plain greedy",
                        draft.name()
                    );
                    assert!(e.leaks().is_empty(), "speculative decode must stay leak-free");
                    assert!(spec.accepted <= spec.proposed, "cannot accept more than proposed");
                    assert!(spec.verify_steps <= steps as u64, "one verify step yields >=1 token");
                    if k == 1 {
                        // Degenerate schedule: no proposals ever made, and
                        // the single-lane flights are the plain path —
                        // byte- and round-identical decode ledger.
                        assert_eq!(spec.proposed, 0);
                        assert_eq!(spec.verify_steps, steps as u64);
                        assert_eq!(out.decode.bytes_total(), plain.decode.bytes_total());
                        assert_eq!(out.decode.rounds_total(), plain.decode.rounds_total());
                    }
                }
            }
        }
    }
}

/// The always-rejected worst case: an adversarial draft proposes a token
/// greedy decode can never emit, so every verify step rolls its whole
/// speculative tail back and keeps exactly one corrected token — the
/// stream still matches plain greedy, and the round bill degrades to the
/// plain schedule (one 16-round flight chain per token), never below it.
#[test]
fn adversarial_draft_rolls_back_every_proposal_with_exact_parity() {
    let cfg = ModelConfig::gpt2_tiny();
    let prompt: Vec<u32> = vec![9, 23, 6];
    let steps = 4usize;
    let (w, rollout) = decisive_weights(&cfg, &prompt, steps, 700);
    let mut plain_e = mk_engine(&cfg, &w, NetworkProfile::lan(), 701);
    let plain = plain_e.generate_streaming(&prompt, steps, &mut |_, _, _| true).unwrap();
    assert_eq!(plain.tokens, rollout);

    let mut e = mk_engine(&cfg, &w, NetworkProfile::lan(), 701);
    let (out, spec) = e.generate_speculative(&prompt, steps, &Draft::Adversarial, 4).unwrap();
    assert_eq!(out.tokens, plain.tokens, "all-reject speculation must still match plain greedy");
    assert!(e.leaks().is_empty());
    assert_eq!(spec.accepted, 0, "the adversarial draft's proposals are never accepted");
    assert_eq!(spec.verify_steps, steps as u64, "one corrected token per verify step");
    // Lane budgets shrink with the remaining step budget (4,3,2,1 lanes),
    // so the draft was asked for 3+2+1+0 proposals.
    assert_eq!(spec.proposed, 6);
    assert_eq!(spec.acceptance_rate(), 0.0);
    // Every verify step is one flight chain at plain-step rounds: with
    // nothing accepted the round bill equals the plain schedule exactly.
    assert_eq!(out.decode.rounds_total(), plain.decode.rounds_total());
}

/// One full `transformer_layer_step` against the caches of a given stack;
/// returns the decoded output row's shares for bit-comparison.
#[allow(clippy::too_many_arguments)]
fn full_step(
    mpc: &mut Mpc,
    backend: &mut NativeBackend,
    views: &mut Views,
    cfg: &ModelConfig,
    pm: &PermutedModel,
    pi1_sh: &Share,
    pi1_t_sh: &Share,
    kv: &mut LayerKvCache,
    x_pi: &FloatTensor,
    t: usize,
) -> (Vec<u64>, Vec<u64>) {
    let row = FloatTensor::from_vec(1, cfg.d, x_pi.row(t).to_vec());
    let row_sh = mpc.share_local(&fixed::encode_tensor(&row));
    let mut ctx = ProtoCtx { mpc, backend, views, fast_sim: false, round_batching: true };
    let out =
        transformer_layer_step(&mut ctx, cfg, &pm.layers[0], pi1_sh, pi1_t_sh, &row_sh, kv, t, 0)
            .unwrap();
    (out.s0.data().to_vec(), out.s1.data().to_vec())
}

/// Rollback vs a never-appended twin, under randomized
/// (step^a, append^r, truncate, step^b) schedules: two stacks with the
/// same seeds run `a` real steps; stack A then appends `r` speculative
/// rows (the correlated append path is deterministic — it consumes
/// correlation bundles, not fresh randomness) and rolls them back, stack
/// B never sees them. Cache digests, correlation `uses_left`, opening
/// counters, and all `b` subsequent step outputs must be share-for-share
/// identical — rollback is invisible to the rest of the session.
#[test]
fn rollback_matches_never_appended_twin_share_for_share() {
    check("rollback == never-appended twin", 4, |g| {
        let mut cfg = ModelConfig::gpt2_tiny();
        cfg.layers = 1;
        let seed = 0x5BEC ^ (g.case as u64).wrapping_mul(0x9E37);
        let w = ModelWeights::random(&cfg, seed);
        let mut prng = Rng::new(seed ^ 1);
        let perms = PermSet::random(&cfg, &mut prng);
        let pm = PermutedModel::build(&cfg, &w, perms.clone());
        let n = cfg.n_ctx;
        let a = 1 + g.below(3); // committed prefix steps
        let r = 1 + g.below(3); // speculative rows, all rejected
        let b = 1 + g.below(2); // post-rollback steps
        let x = FloatTensor::from_fn(n, cfg.d, |row, col| {
            ((row * 13 + col * 7 + g.case * 3) % 23) as f32 * 0.04 - 0.4
        });
        let x_pi = perms.pi.apply_cols(&x);

        // Two identical stacks (same mpc seed => same share masks, same
        // dealer stream) with per-layer correlated caches.
        let mut stacks = Vec::new();
        for _ in 0..2 {
            let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), seed ^ 2);
            let backend = NativeBackend::new();
            let views = Views::new(false);
            let pi1_sh = ppp::share_perm(&mut mpc, &perms.pi1, OpClass::Linear);
            let pi1_t_sh = ppp::share_perm_t(&mut mpc, &perms.pi1, OpClass::Linear);
            let corr = deal_kv_correlations(&mut mpc, &cfg, &pi1_sh, &pi1_t_sh).unwrap();
            let kv = LayerKvCache::with_correlations(n, cfg.d, corr);
            stacks.push((mpc, backend, views, pi1_sh, pi1_t_sh, kv));
        }

        // Committed prefix: identical on both stacks.
        for t in 0..a {
            for (mpc, backend, views, pi1_sh, pi1_t_sh, kv) in stacks.iter_mut() {
                full_step(mpc, backend, views, &cfg, &pm, pi1_sh, pi1_t_sh, kv, &x_pi, t);
            }
        }

        // Speculative rows: both stacks *share* the rows (keeping the mask
        // PRGs in lockstep — sharing is client-side), but only stack A
        // appends them and rolls back.
        for j in 0..r {
            let krow = FloatTensor::from_vec(1, cfg.d, x_pi.row(a + j).to_vec());
            let vrow = FloatTensor::from_vec(1, cfg.d, x_pi.row((a + j + 1) % n).to_vec());
            for (i, (mpc, backend, views, _, pi1_t_sh, kv)) in stacks.iter_mut().enumerate() {
                let k_sh = mpc.share_local(&fixed::encode_tensor(&krow));
                let v_sh = mpc.share_local(&fixed::encode_tensor(&vrow));
                if i == 0 {
                    let mut ctx = ProtoCtx {
                        mpc,
                        backend,
                        views,
                        fast_sim: false,
                        round_batching: true,
                    };
                    kv.append(&mut ctx, pi1_t_sh, &k_sh, &v_sh, a + j).unwrap();
                }
            }
        }
        assert_eq!(stacks[0].5.len(), a + r);
        stacks[0].5.truncate_to(a).unwrap();

        // Share-for-share state identity: digest + correlation counters.
        assert_eq!(
            stacks[0].5.state_digest(),
            stacks[1].5.state_digest(),
            "case {}: rollback must restore the exact twin cache state (a={a} r={r})",
            g.case
        );
        let snap = |kv: &LayerKvCache| {
            let c = kv.correlations().unwrap();
            (
                c.ppp.uses_left(),
                c.append.uses_left(),
                c.scores.uses_left(),
                c.ppp.openings(),
                c.append.openings(),
                c.scores.openings(),
            )
        };
        assert_eq!(snap(&stacks[0].5), snap(&stacks[1].5), "correlation counters must match the twin");

        // Every subsequent step must be bit-identical: rollback restored
        // the same consumed bundles, and appends drew no fresh randomness.
        for t in a..a + b {
            let mut outs = Vec::new();
            for (mpc, backend, views, pi1_sh, pi1_t_sh, kv) in stacks.iter_mut() {
                outs.push(full_step(mpc, backend, views, &cfg, &pm, pi1_sh, pi1_t_sh, kv, &x_pi, t));
            }
            assert_eq!(outs[0], outs[1], "case {}: step {t} shares diverged after rollback", g.case);
        }
        assert_eq!(stacks[0].5.state_digest(), stacks[1].5.state_digest());
    });
}

/// Demand accounting closes the speculative loop: a session registers
/// lane-scaled per-step value-triple demand
/// ([`layer::decode_pool_shapes_speculative`]); eviction hands back
/// exactly the unconsumed share, so an untouched session balances to zero
/// while the fixed correlation bundles (dealt at admission) stay spent.
#[test]
fn evicted_speculative_session_pool_demand_balances_to_zero() {
    let cfg = ModelConfig::gpt2_tiny();
    let (steps, spec_k) = (6u64, 4u64);
    let pool = TriplePool::new(1, 2);
    let shapes = layer::decode_pool_shapes_speculative(&cfg, true, steps, 1, spec_k);
    for &(shape, count) in &shapes {
        pool.register_demand(shape, count);
    }
    let value_shape = TripleShape::matmul(1, cfg.n_ctx, cfg.dh());
    let per_step_lane = cfg.layers as u64 * cfg.h as u64;
    assert_eq!(
        pool.demand_for(value_shape),
        per_step_lane * steps * spec_k,
        "value-triple demand must scale with the verify lanes"
    );

    // Eviction before any step ran: all steps unconsumed, lane-scaled —
    // the same arithmetic the coordinator's release path applies.
    pool.release_demand(value_shape, per_step_lane * steps * spec_k);
    assert_eq!(pool.demand_for(value_shape), 0, "demand must balance to zero after eviction");
    for &(shape, count) in shapes.iter().filter(|(s, _)| s.is_fixed()) {
        assert_eq!(
            pool.demand_for(shape),
            count,
            "correlation bundles are dealt at admission and stay registered"
        );
    }

    // Partial consumption: 2 of 6 steps ran, eviction releases the other
    // 4 — exactly the consumed share remains registered.
    pool.register_demand(value_shape, per_step_lane * steps * spec_k);
    pool.release_demand(value_shape, per_step_lane * (steps - 2) * spec_k);
    assert_eq!(pool.demand_for(value_shape), per_step_lane * 2 * spec_k);
}
