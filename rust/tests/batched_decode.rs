//! Continuous-batching parity suite (DESIGN.md §Continuous batching).
//!
//! The batch axis must be *free* at B=1: a [`DecodeBatch`] holding one
//! session runs the exact op sequence of a solo [`DecoderSession`], so
//! tokens, ledgers, the transfer census, and P1's view census are pinned
//! bit-identical here. At B>1 the dealer's randomness interleaves across
//! lanes, so shares differ from a solo run while each session's *token
//! stream* still matches the plaintext greedy rollout wherever that
//! rollout is decisive (the same margin-gating convention as
//! `e2e_pipeline.rs`), and wire rounds amortize to (solo rounds)/B.

use centaur::data::{greedy_regular_token, NUM_SPECIAL_TOKENS};
use centaur::engine::decoder::{DecodeBatch, DecoderSession};
use centaur::engine::{CentaurEngine, EngineOptions};
use centaur::model::{plaintext, ModelConfig, ModelWeights, Variant};
use centaur::runtime::NativeBackend;
use centaur::util::prop::check;

/// Fixed-point noise on tiny-model logits is ~1e-3; 0.03 is 30x that
/// (same bound as the solo decode parity suite).
const DECODE_MARGIN: f32 = 0.03;

fn mk_engine(cfg: &ModelConfig, w: &ModelWeights, seed: u64, census: bool) -> CentaurEngine {
    CentaurEngine::with_backend(
        cfg,
        w,
        Box::new(NativeBackend::new()),
        EngineOptions { seed, record_views: census, record_transfers: census, ..Default::default() },
    )
    .unwrap()
}

/// Margin-gated plaintext greedy rollout: `(token, decisive)` per step.
/// Comparisons against protocol paths are only meaningful on the decisive
/// *prefix* — after the first indecisive step the greedy continuations may
/// legitimately diverge and everything downstream is chained off that.
fn margin_gated_rollout(
    cfg: &ModelConfig,
    w: &ModelWeights,
    prompt: &[u32],
    steps: usize,
) -> Vec<(u32, bool)> {
    let mut seq = prompt.to_vec();
    let mut expected = Vec::with_capacity(steps);
    for _ in 0..steps {
        let mut padded = seq.clone();
        padded.resize(cfg.n_ctx, 0);
        let logits = plaintext::forward(cfg, w, &padded, Variant::Exact);
        let row = logits.row(seq.len() - 1);
        let tok = greedy_regular_token(row);
        let (mut best, mut second) = (f32::NEG_INFINITY, f32::NEG_INFINITY);
        for &v in row.iter().skip(NUM_SPECIAL_TOKENS) {
            if v > best {
                second = best;
                best = v;
            } else if v > second {
                second = v;
            }
        }
        expected.push((tok, best - second >= DECODE_MARGIN));
        seq.push(tok);
    }
    expected
}

/// Number of leading rollout steps that are all decisive — the span over
/// which greedy token streams are forced and may be compared exactly.
fn decisive_prefix(expected: &[(u32, bool)]) -> usize {
    expected.iter().position(|&(_, d)| !d).unwrap_or(expected.len())
}

/// B=1 is the identity case of the batch axis: one admitted session must
/// be *bit*-identical to a solo [`DecoderSession`] on the same engine
/// seed — same tokens and logits (same PRG stream), same per-phase
/// byte/round ledgers, the same transfer log in the same order, and a
/// record-for-record equal P1 view census including payloads.
#[test]
fn single_session_batch_is_bit_identical_to_decoder_session() {
    const STEPS: usize = 3;
    check("B=1 batch == solo session", 3, |g| {
        let cfg = ModelConfig::gpt2_tiny();
        let seed = 0xBA7C4 ^ (g.case as u64).wrapping_mul(7919);
        let w = ModelWeights::random(&cfg, seed);
        let prompt: Vec<u32> = (0..3)
            .map(|_| (g.below(cfg.vocab - NUM_SPECIAL_TOKENS) + NUM_SPECIAL_TOKENS) as u32)
            .collect();

        // Solo reference run.
        let mut e_solo = mk_engine(&cfg, &w, seed ^ 0x5, true);
        let mut solo_tokens = Vec::with_capacity(STEPS);
        let (solo_setup, solo_prefill, solo_decode, solo_logits) = {
            let mut sess = DecoderSession::new(&mut e_solo, &prompt).unwrap();
            for _ in 0..STEPS {
                solo_tokens.push(sess.step_greedy().unwrap());
            }
            (
                sess.setup_cost().clone(),
                sess.prefill_cost().clone(),
                sess.decode_cost().clone(),
                sess.logits().clone(),
            )
        };
        assert!(e_solo.leaks().is_empty());

        // Batched run on an engine with the identical seed.
        let mut e_b = mk_engine(&cfg, &w, seed ^ 0x5, true);
        let summary = {
            let mut batch = DecodeBatch::new(&mut e_b).unwrap();
            let id = batch.admit(&prompt, STEPS, None).unwrap();
            let mut b_tokens = Vec::with_capacity(STEPS);
            loop {
                let emissions = batch.step().unwrap();
                if emissions.is_empty() {
                    break;
                }
                for em in &emissions {
                    assert_eq!(em.session, id);
                    b_tokens.push(em.token);
                }
            }
            assert_eq!(b_tokens, solo_tokens, "token stream must be bit-identical at B=1");

            let s = batch.session(id).unwrap();
            assert_eq!(s.logits().data(), solo_logits.data(), "final logits must be bit-identical");
            assert_eq!(s.setup_cost().bytes_total(), solo_setup.bytes_total());
            assert_eq!(s.setup_cost().rounds_total(), solo_setup.rounds_total());
            assert_eq!(s.prefill_bytes(), solo_prefill.bytes_total());
            assert_eq!(s.prefill_rounds(), solo_prefill.rounds_total());
            assert_eq!(s.decode_bytes(), solo_decode.bytes_total());
            assert_eq!(s.decode_rounds(), solo_decode.rounds_total());
            assert_eq!(s.decode_steps(), STEPS as u64);

            assert_eq!(batch.batch_decode_steps(), STEPS as u64);
            assert_eq!(batch.batch_tokens(), STEPS as u64);
            assert_eq!(batch.max_concurrent(), 1);
            batch.remove(id).unwrap()
        };
        assert_eq!(summary.tokens, solo_tokens);
        assert_eq!(summary.steps_unconsumed, 0);
        assert!(e_b.leaks().is_empty());

        // Transfer census: same messages, same payloads, same order — the
        // batched path at B=1 is the solo path, not merely equivalent.
        assert_eq!(e_solo.transfer_log(), e_b.transfer_log(), "transfer logs must match in order");

        // P1 view census: record-for-record equal including payload bits.
        assert_eq!(e_solo.views.p1.len(), e_b.views.p1.len());
        for (sv, bv) in e_solo.views.p1.iter().zip(&e_b.views.p1) {
            assert_eq!(sv.label, bv.label);
            assert_eq!(sv.tag, bv.tag);
            assert_eq!((sv.rows, sv.cols), (bv.rows, bv.cols));
            assert_eq!(
                sv.tensor.as_ref().unwrap().data(),
                bv.tensor.as_ref().unwrap().data(),
                "view payload {} differs",
                sv.label
            );
        }
    });
}

/// B=4: four sessions admitted up front all ride the same flights. Each
/// session's stream must match its own plaintext greedy rollout over the
/// decisive prefix (and hence its solo protocol stream, which the solo
/// parity suite pins to the same rollout), and the amortized wire rounds
/// per token must come in at (solo rounds)/4 — well under the ≤8
/// acceptance bound for gpt2-tiny's 16-round solo step.
#[test]
fn four_session_batch_matches_solo_streams_and_amortizes_rounds() {
    const STEPS: usize = 4;
    const B: usize = 4;
    let cfg = ModelConfig::gpt2_tiny();
    let seed = 0xB47C8u64;
    let w = ModelWeights::random(&cfg, seed);
    let base = NUM_SPECIAL_TOKENS as u32;
    let prompts: Vec<Vec<u32>> =
        (0..B as u32).map(|i| vec![base + 3 + i * 5, base + 7 + i, base + 2 + i * 2]).collect();
    let rollouts: Vec<Vec<(u32, bool)>> =
        prompts.iter().map(|p| margin_gated_rollout(&cfg, &w, p, STEPS)).collect();

    // Solo per-step wire rounds, as the amortization denominator.
    let mut e_solo = mk_engine(&cfg, &w, seed ^ 0x11, false);
    let solo_step_rounds = {
        let mut sess = DecoderSession::new(&mut e_solo, &prompts[0]).unwrap();
        sess.step_greedy().unwrap();
        sess.last_step_cost().rounds_total()
    };
    assert!(solo_step_rounds > 0);

    let mut e_b = mk_engine(&cfg, &w, seed ^ 0x11, false);
    let mut batch = DecodeBatch::new(&mut e_b).unwrap();
    let ids: Vec<usize> =
        prompts.iter().map(|p| batch.admit(p, STEPS, None).unwrap()).collect();
    let mut streams: Vec<Vec<u32>> = vec![Vec::new(); B];
    loop {
        let emissions = batch.step().unwrap();
        if emissions.is_empty() {
            break;
        }
        for em in &emissions {
            let lane = ids.iter().position(|&id| id == em.session).unwrap();
            streams[lane].push(em.token);
        }
    }

    for (lane, stream) in streams.iter().enumerate() {
        assert_eq!(stream.len(), STEPS, "session {lane} must run its full step budget");
        let n = decisive_prefix(&rollouts[lane]);
        for (s, (&got, &(want, _))) in stream.iter().zip(&rollouts[lane]).take(n).enumerate() {
            assert_eq!(
                got, want,
                "session {lane} step {s}: batched greedy diverged from the decisive plaintext rollout"
            );
        }
    }

    // All four sessions share every step's flights: 4 tokens per step at
    // solo wire rounds → amortized rounds/token = solo/4.
    assert_eq!(batch.batch_decode_steps(), STEPS as u64);
    assert_eq!(batch.batch_tokens(), (B * STEPS) as u64);
    assert_eq!(batch.max_concurrent(), B);
    assert_eq!(batch.batch_wire_rounds(), STEPS as u64 * solo_step_rounds);
    let amortized = batch.amortized_rounds_per_token();
    assert!(
        (amortized - solo_step_rounds as f64 / B as f64).abs() < 1e-9,
        "amortized {amortized} != solo/{B}"
    );
    assert!(amortized <= 8.0, "amortized rounds/token {amortized} exceeds the acceptance bound");

    for &id in &ids {
        let summary = batch.remove(id).unwrap();
        assert_eq!(summary.tokens.len(), STEPS);
        assert_eq!(summary.steps_unconsumed, 0);
        assert_eq!(summary.decode_rounds, STEPS as u64 * solo_step_rounds);
    }
    assert!(batch.is_empty());
    drop(batch);
    assert!(e_b.leaks().is_empty());
}

/// Continuous-batching lifecycle plumbing: sessions admitted mid-stream
/// join the shared flights at the next step boundary, early eviction
/// reports the unconsumed step budget, and the batch counters reconcile
/// with the per-emission accounting throughout.
#[test]
fn staggered_admission_and_early_eviction_keep_counters_consistent() {
    let cfg = ModelConfig::gpt2_tiny();
    let w = ModelWeights::random(&cfg, 0x57A66);
    let base = NUM_SPECIAL_TOKENS as u32;
    let mut eng = mk_engine(&cfg, &w, 0x57A66 ^ 0x3, false);
    let mut batch = DecodeBatch::new(&mut eng).unwrap();

    let s0 = batch.admit(&[base + 3, base + 7], 6, None).unwrap();
    let mut step_rounds = 0u64;
    for _ in 0..2 {
        let emissions = batch.step().unwrap();
        assert_eq!(emissions.len(), 1);
        assert_eq!(emissions[0].session, s0);
        step_rounds = emissions[0].step_rounds;
        assert!(step_rounds > 0);
    }

    // s1 joins at a step boundary and immediately shares the flights.
    let s1 = batch.admit(&[base + 11, base + 1], 4, None).unwrap();
    assert_eq!(batch.len(), 2);
    assert_eq!(batch.active(), 2);
    let emissions = batch.step().unwrap();
    assert_eq!(emissions.len(), 2);
    assert_eq!(emissions[0].step_rounds, step_rounds, "shared step keeps the solo round count");
    assert_eq!(emissions[1].step_rounds, step_rounds);

    // Early eviction after one consumed step: 3 of 4 steps unconsumed.
    let evicted = batch.remove(s1).unwrap();
    assert_eq!(evicted.tokens.len(), 1);
    assert_eq!(evicted.steps_unconsumed, 3);
    assert_eq!(batch.len(), 1);

    // s0 runs out its remaining budget solo. Emitted so far: 2 solo-lane
    // steps (s0) + one 2-lane step (s0 + the evicted s1) = 4 tokens.
    let mut total_tokens = 4u64;
    let mut s0_tokens = 3usize;
    loop {
        let emissions = batch.step().unwrap();
        if emissions.is_empty() {
            break;
        }
        assert_eq!(emissions.len(), 1);
        s0_tokens += 1;
        total_tokens += 1;
    }
    assert_eq!(s0_tokens, 6);
    let done = batch.session(s0).unwrap();
    assert!(done.is_done());
    assert_eq!(done.decode_steps(), 6);

    assert_eq!(batch.batch_decode_steps(), 6);
    assert_eq!(batch.batch_tokens(), total_tokens);
    assert_eq!(batch.batch_tokens(), 7); // 5 solo-lane steps + one 2-lane step
    assert_eq!(batch.batch_wire_rounds(), 6 * step_rounds);
    assert_eq!(batch.max_concurrent(), 2);

    let summary = batch.remove(s0).unwrap();
    assert_eq!(summary.tokens.len(), 6);
    assert_eq!(summary.steps_unconsumed, 0);
    assert!(batch.is_empty());
    assert!(batch.step().unwrap().is_empty(), "an empty batch steps to an empty emission set");
}
