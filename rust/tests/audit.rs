//! Tamper-injection harness for integrity-checked inference
//! (DESIGN.md §Integrity-checked inference).
//!
//! The property under test: with [`EngineOptions::audit`] on, *any*
//! single fault — one bit flipped in one delivered transfer, one stale
//! message replayed, or one share perturbed at one opening — is rejected
//! by the deferred share-MAC check or by transcript verification, while
//! honest runs verify clean and stay **bit-identical** to audit-off runs
//! (tokens, ledgers, payload chains — the audit layer's only observable
//! cost lives in [`centaur::mpc::AuditCounters`]).
//!
//! The tamper grid covers 3 seeds × {lan, wan3} × {solo, batched B=4,
//! speculative k=4}, rotating the fault kind per cell so every kind runs
//! under every mode. Fault positions are drawn pseudo-randomly from the
//! *request's own* span of an identically-seeded honest twin: engine
//! construction (permutation dealing) already consumes transfer and
//! opening indices, so a position below the post-construction watermark
//! would never fire.

use centaur::engine::audit::{verify_transcript, RequestTranscript};
use centaur::engine::decoder::DecodeBatch;
use centaur::engine::draft::Draft;
use centaur::engine::{CentaurEngine, EngineOptions};
use centaur::model::{ModelConfig, ModelWeights};
use centaur::mpc::ShareFault;
use centaur::net::{NetworkProfile, TamperKind, TamperPlan};
use centaur::runtime::NativeBackend;
use centaur::util::rng::splitmix64;

const PROMPT: [u32; 2] = [5, 9];
const STEPS: usize = 2;
const BATCH: u32 = 4;
const SPEC_K: usize = 4;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Mode {
    /// One `DecoderSession` via `generate_streaming`.
    Solo,
    /// A `DecodeBatch` holding `BATCH` concurrent sessions.
    Batched,
    /// Speculative decode (`generate_speculative`, draft = tiny model).
    Spec,
}

const MODES: [Mode; 3] = [Mode::Solo, Mode::Batched, Mode::Spec];

/// One engine run plus the audit-side observations the harness asserts
/// on. `pre_*` are the post-construction watermarks faults must clear.
struct Run {
    result: centaur::Result<(Vec<u32>, RequestTranscript)>,
    pre_transfers: u64,
    post_transfers: u64,
    pre_opens: u64,
    post_opens: u64,
    counters: Option<centaur::mpc::AuditCounters>,
    faults_applied: u64,
}

fn exec(
    eng: &mut CentaurEngine,
    cfg: &ModelConfig,
    w: &ModelWeights,
    mode: Mode,
) -> centaur::Result<(Vec<u32>, RequestTranscript)> {
    match mode {
        Mode::Solo => {
            let out = eng.generate_streaming(&PROMPT, STEPS, &mut |_, _, _| true)?;
            Ok((out.tokens, out.transcript))
        }
        Mode::Spec => {
            let draft = Draft::tiny(cfg, w);
            let (out, _) = eng.generate_speculative(&PROMPT, STEPS, &draft, SPEC_K)?;
            Ok((out.tokens, out.transcript))
        }
        Mode::Batched => {
            let mut batch = DecodeBatch::new(eng)?;
            let mut ids = Vec::new();
            for i in 0..BATCH {
                ids.push(batch.admit(&[PROMPT[0], PROMPT[1] + i], STEPS, None)?);
            }
            while !batch.step()?.is_empty() {}
            let transcript = batch.transcript();
            let mut tokens = Vec::new();
            for id in ids {
                tokens.extend(batch.remove(id).expect("admitted session").tokens);
            }
            Ok((tokens, transcript))
        }
    }
}

fn run_mode(
    cfg: &ModelConfig,
    w: &ModelWeights,
    profile: &str,
    seed: u64,
    mode: Mode,
    audit: bool,
    wire: Option<TamperPlan>,
    share: Option<ShareFault>,
) -> Run {
    let mut eng = CentaurEngine::with_backend(
        cfg,
        w,
        Box::new(NativeBackend::new()),
        EngineOptions {
            profile: NetworkProfile::by_name(profile).unwrap(),
            seed,
            record_transfers: true,
            audit,
            ..Default::default()
        },
    )
    .unwrap();
    let pre_transfers = eng.transfer_count();
    let pre_opens = eng.audit_open_count();
    if let Some(p) = wire {
        eng.schedule_tamper(p);
    }
    if let Some(f) = share {
        assert!(eng.inject_share_fault(f), "share faults need audit mode on");
    }
    let result = exec(&mut eng, cfg, w, mode);
    Run {
        result,
        pre_transfers,
        post_transfers: eng.transfer_count(),
        pre_opens,
        post_opens: eng.audit_open_count(),
        counters: eng.audit_counters(),
        faults_applied: eng.faults_applied(),
    }
}

/// The headline property: every cell of the 3 × 2 × 3 grid injects one
/// fault (kind rotating per cell, position pseudo-random within the
/// honest twin's request span) and the fault is always rejected — by the
/// MAC flush bailing, by a counted MAC failure, or by the replayed
/// transcript diverging from the honest one.
#[test]
fn tamper_grid_every_injected_fault_is_detected() {
    let cfg = ModelConfig::gpt2_tiny();
    let w = ModelWeights::random(&cfg, 117);
    let mut cell = 0u64;
    for seed in [0xA11D_31u64, 0xA11D_32, 0xA11D_33] {
        for profile in ["lan", "wan3"] {
            for mode in MODES {
                // Honest twin: must verify clean, and supplies the
                // request's transfer/opening span for fault placement.
                let honest = run_mode(&cfg, &w, profile, seed, mode, true, None, None);
                let (h_tokens, h_tr) = honest.result.as_ref().expect("honest run must succeed");
                assert!(!h_tokens.is_empty());
                let hc = honest.counters.unwrap();
                assert_eq!(hc.mac_failures, 0, "honest run must verify clean ({profile}/{mode:?})");
                assert!(hc.mac_checks > 0, "audited run must actually check ({profile}/{mode:?})");
                let transfers = honest.post_transfers - honest.pre_transfers;
                let opens = honest.post_opens - honest.pre_opens;
                assert!(transfers > 0 && opens > 0, "request must transfer and open");

                let mut st = seed ^ (cell << 17) ^ 0x7A3F_0001;
                let r = splitmix64(&mut st);
                let (wire, share) = match cell % 3 {
                    0 => (
                        Some(TamperPlan {
                            at_seq: honest.pre_transfers + r % transfers,
                            kind: TamperKind::BitFlip {
                                word: (r >> 8) as usize,
                                bit: ((r >> 32) % 64) as u32,
                            },
                        }),
                        None,
                    ),
                    1 => (
                        Some(TamperPlan {
                            at_seq: honest.pre_transfers + r % transfers,
                            kind: TamperKind::ReplayStale,
                        }),
                        None,
                    ),
                    _ => (
                        None,
                        Some(ShareFault {
                            at_open: honest.pre_opens + r % opens,
                            word: (r >> 8) as usize,
                            mask: 1 << ((r >> 32) % 64),
                        }),
                    ),
                };

                let t = run_mode(&cfg, &w, profile, seed, mode, true, wire, share);
                if wire.is_some() {
                    assert_eq!(
                        t.faults_applied, 1,
                        "cell {cell} ({profile}/{mode:?}): scheduled wire fault never landed"
                    );
                }
                if share.is_some() {
                    assert_eq!(
                        t.counters.unwrap().share_faults_applied,
                        1,
                        "cell {cell} ({profile}/{mode:?}): injected share fault never fired"
                    );
                }
                let detected = match &t.result {
                    Err(_) => true,
                    Ok((_, tr)) => {
                        t.counters.is_some_and(|c| c.mac_failures > 0)
                            || h_tr.first_divergence(tr).is_some()
                    }
                };
                assert!(
                    detected,
                    "cell {cell} (seed {seed:#x}, {profile}, {mode:?}, wire {wire:?}, share \
                     {share:?}): the fault went UNDETECTED"
                );
                cell += 1;
            }
        }
    }
}

/// Zero-perturbation invariant: turning audit on must not move a single
/// bit of the inference itself. Tokens, per-step ledger commitments, the
/// core digest, *and the payload wire chain* are equal to the audit-off
/// run; only the audit counters differ (present and nonzero vs absent).
#[test]
fn honest_audited_runs_verify_clean_and_match_audit_off_bit_for_bit() {
    let cfg = ModelConfig::gpt2_tiny();
    let w = ModelWeights::random(&cfg, 118);
    for mode in MODES {
        let on = run_mode(&cfg, &w, "lan", 0xC1EA4, mode, true, None, None);
        let off = run_mode(&cfg, &w, "lan", 0xC1EA4, mode, false, None, None);
        let (tok_on, tr_on) = on.result.expect("audited run");
        let (tok_off, tr_off) = off.result.expect("semi-honest run");
        assert_eq!(tok_on, tok_off, "audit must not perturb tokens ({mode:?})");
        assert_eq!(tr_on.commits(), tr_off.commits(), "audit must not perturb ledgers ({mode:?})");
        assert_eq!(tr_on.core_digest(), tr_off.core_digest());
        assert_eq!(
            tr_on.wire_digest(),
            tr_off.wire_digest(),
            "audit must not perturb a single payload bit ({mode:?})"
        );
        assert!(tr_on.wire_digest().is_some(), "census-on full runs carry a wire chain");
        // The σ-exchange is emulated: counted in AuditCounters, never on
        // the simulated wire.
        assert_eq!(
            on.post_transfers - on.pre_transfers,
            off.post_transfers - off.pre_transfers,
            "audit overhead must stay off the protocol transfer stream ({mode:?})"
        );
        let c = on.counters.expect("audit-on exposes counters");
        assert!(c.mac_checks > 0 && c.openings > 0, "({mode:?}) counters: {c:?}");
        assert_eq!(c.mac_failures, 0);
        assert_eq!(c.overhead_bytes, 32 * c.mac_checks, "32 σ-bytes per flush");
        assert!(off.counters.is_none(), "audit-off exposes no counters");
    }
}

/// The transcript's core digest commits only to quantities pinned
/// execution-mode-independent elsewhere (ledger deltas, lanes, greedy
/// tokens), so the same seeded request digests identically under
/// fast-sim or full execution, lan or wan3, scalar or SIMD ring kernels.
/// The wire chain is the intentional exception: it exists only for full
/// runs with the census on — and *is* profile- and kernel-independent.
#[test]
fn transcript_core_digest_is_mode_profile_and_kernel_independent() {
    let cfg = ModelConfig::gpt2_tiny();
    let w = ModelWeights::random(&cfg, 119);
    let run = |fast: bool, profile: &str| {
        let mut eng = CentaurEngine::with_backend(
            &cfg,
            &w,
            Box::new(NativeBackend::new()),
            EngineOptions {
                profile: NetworkProfile::by_name(profile).unwrap(),
                seed: 53,
                fast_sim: fast,
                record_transfers: !fast,
                audit: true,
                ..Default::default()
            },
        )
        .unwrap();
        let out = eng.generate_streaming(&PROMPT, STEPS, &mut |_, _, _| true).unwrap();
        (out.tokens, out.transcript)
    };
    let (tok, tr) = run(false, "lan");
    // Profile independence, full mode — including the payload chain.
    let (tok_wan, tr_wan) = run(false, "wan3");
    assert_eq!(tok, tok_wan);
    assert_eq!(tr.core_digest(), tr_wan.core_digest());
    assert_eq!(tr.wire_digest().expect("full mode"), tr_wan.wire_digest().expect("full mode"));
    // Fast-sim twin: identical step commitments, tokens, and core digest;
    // no wire chain to compare.
    let (tok_fast, tr_fast) = run(true, "lan");
    assert_eq!(tr_fast.wire_digest(), None, "fast-sim carries no payload chain");
    assert_eq!(tr.commits(), tr_fast.commits(), "fast-sim must charge identical step ledgers");
    assert_eq!(tok, tok_fast, "fast-sim greedy tokens must match full execution");
    assert_eq!(tr.core_digest(), tr_fast.core_digest());
    let (_, tr_fast_wan) = run(true, "wan3");
    assert_eq!(tr_fast.core_digest(), tr_fast_wan.core_digest());
    // Kernel independence: the scalar ring kernel is bit-identical to the
    // SIMD dispatch, so even the wire chain must match. (The override is
    // process-global, but all kernels compute identical ring values, so
    // concurrently running tests are unaffected.)
    centaur::runtime::kernel::set_override(Some("scalar")).unwrap();
    let scalar = run(false, "lan");
    centaur::runtime::kernel::set_override(None).unwrap();
    let (tok_s, tr_s) = scalar;
    assert_eq!(tok, tok_s);
    assert_eq!(tr.core_digest(), tr_s.core_digest());
    assert_eq!(tr.wire_digest(), tr_s.wire_digest(), "ring kernels are bit-identical");
}

/// End-to-end `verify_transcript`: an honest re-execution of the same
/// seeded request verifies; a tampered re-execution is rejected (either
/// its MAC flush bails or its transcript diverges); and a request of a
/// different shape or seed is never accepted as a replay.
#[test]
fn verify_transcript_accepts_honest_replays_and_rejects_divergent_ones() {
    let cfg = ModelConfig::gpt2_tiny();
    let w = ModelWeights::random(&cfg, 120);
    let honest = run_mode(&cfg, &w, "lan", 61, Mode::Solo, true, None, None);
    let transfers = honest.post_transfers - honest.pre_transfers;
    let (_, recorded) = honest.result.expect("honest run");

    // Same seed, same inputs, fresh engine: verifies.
    let replay = run_mode(&cfg, &w, "lan", 61, Mode::Solo, true, None, None);
    verify_transcript(&recorded, || replay.result.map(|(_, t)| t))
        .expect("an honest replay must verify");

    // A re-execution with one bit flipped on the wire: rejected.
    let tampered = run_mode(
        &cfg,
        &w,
        "lan",
        61,
        Mode::Solo,
        true,
        Some(TamperPlan {
            at_seq: honest.pre_transfers + transfers / 2,
            kind: TamperKind::BitFlip { word: 3, bit: 41 },
        }),
        None,
    );
    let err = verify_transcript(&recorded, || tampered.result.map(|(_, t)| t)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("transcript verification failed") || msg.contains("MAC check failed"),
        "got: {msg}"
    );

    // A longer request is structurally not a replay (step-count
    // divergence), independent of any wire evidence.
    let longer = {
        let mut eng = CentaurEngine::with_backend(
            &cfg,
            &w,
            Box::new(NativeBackend::new()),
            EngineOptions {
                seed: 61,
                record_transfers: true,
                audit: true,
                ..Default::default()
            },
        )
        .unwrap();
        eng.generate_streaming(&PROMPT, STEPS + 1, &mut |_, _, _| true).unwrap().transcript
    };
    let err = verify_transcript(&recorded, || Ok(longer)).unwrap_err();
    assert!(format!("{err:#}").contains("step count"), "got: {err:#}");

    // A different session seed reshapes every mask: the payload chain
    // diverges even though ledgers (and typically tokens) agree.
    let other = run_mode(&cfg, &w, "lan", 62, Mode::Solo, true, None, None);
    let err = verify_transcript(&recorded, || other.result.map(|(_, t)| t)).unwrap_err();
    assert!(format!("{err:#}").contains("transcript verification failed"), "got: {err:#}");
}
