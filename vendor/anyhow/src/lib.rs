//! Minimal offline shim of the [`anyhow`](https://docs.rs/anyhow) API.
//!
//! The offline crate mirror used to build this repository has no crates.io
//! access (DESIGN.md §Offline-dependency substitutions), so this vendored
//! path dependency implements the subset of `anyhow` the codebase uses:
//!
//! * [`Error`] — an opaque, `Send + Sync` error value with a message and an
//!   optional source chain,
//! * [`Result`] — `Result<T, Error>` with the same defaulted type parameter
//!   as the real crate,
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `impl From<E: std::error::Error> for Error` coherent and lets `?`
//! convert any standard error type.

#![warn(missing_docs)]

use std::error::Error as StdError;
use std::fmt;

/// An opaque error value: a rendered message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap a standard error, keeping it as the source.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Self {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Prepend context to the message (a tiny subset of `anyhow::Context`).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The root-cause message chain, outermost first.
    pub fn chain_messages(&self) -> Vec<String> {
        let mut out = vec![self.msg.clone()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> =
            self.source.as_deref().map(|e| e as _);
        while let Some(e) = cur {
            out.push(e.to_string());
            cur = e.source();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        // `{:#}` renders the full cause chain inline, like the real crate.
        if f.alternate() {
            let mut cur: Option<&(dyn std::error::Error + 'static)> =
                self.source.as_deref().map(|e| e as _);
            while let Some(e) = cur {
                write!(f, ": {e}")?;
                cur = e.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur: Option<&(dyn std::error::Error + 'static)> =
            self.source.as_deref().map(|e| e as _);
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {e}")?;
            cur = e.source();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `Result<T, anyhow::Error>` with the error type defaulted, so both
/// `anyhow::Result<T>` and `anyhow::Result<T, E>` spellings work.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built from the arguments.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
        assert_eq!(e.chain_messages().len(), 2);
    }

    #[test]
    fn macros_build_messages() {
        let x = 41;
        let e = anyhow!("answer {} off by {x}", 42);
        assert_eq!(e.to_string(), "answer 42 off by 41");

        fn bails() -> Result<()> {
            bail!("nope: {}", 7);
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope: 7");

        fn ensures(v: usize) -> Result<usize> {
            ensure!(v < 10, "v too big: {v}");
            ensure!(v != 3);
            Ok(v)
        }
        assert_eq!(ensures(2).unwrap(), 2);
        assert!(ensures(12).unwrap_err().to_string().contains("too big"));
        assert!(ensures(3).unwrap_err().to_string().contains("condition failed"));
    }

    #[test]
    fn alternate_display_shows_chain() {
        let e = Error::new(io_err()).context("loading weights");
        let plain = format!("{e}");
        let alt = format!("{e:#}");
        assert!(plain.starts_with("loading weights"));
        assert!(alt.contains("gone"));
    }
}
