//! Permutation-only PPTI leakage demo (paper §3, Motivation 2): the
//! Yuan-et-al.-style baseline is nearly as fast as plaintext, but the leak
//! detector shows every `O1/O4/O5/O6` exposed in unpermuted plaintext,
//! and a SIP attack on those exposures recovers the input.
//!
//! ```bash
//! make artifacts && cargo run --release --example permonly_leakage
//! ```

use centaur::baselines::permonly::PermOnlyEngine;
use centaur::baselines::PptiFramework;
use centaur::data::{artifacts_dir, AttackCorpora, Vocab};
use centaur::model::ModelWeights;
use centaur::net::NetworkProfile;
use centaur::util::cli::Args;

fn main() -> centaur::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let dir = args.opt_or("artifacts", &artifacts_dir()).to_string();
    let vocab = Vocab::load(&dir)?;
    let corpora = AttackCorpora::load(&dir)?;
    let (cfg, w) = ModelWeights::load_tag(&dir, "gpt2-tiny-wikitext103")?;

    let victim = &corpora.private[0];
    println!("victim input: {}\n", vocab.decode(victim));

    let mut engine = PermOnlyEngine::new(&cfg, &w, NetworkProfile::lan(), true);
    let out = engine.infer(victim)?;
    println!(
        "permutation-only PPTI: {} comm, {} rounds — near-plaintext efficiency",
        centaur::util::human_bytes(out.stats.bytes_total()),
        out.stats.rounds_total()
    );
    let leaks = engine.views.leaks();
    println!("leak detector: {} unpermuted intermediates exposed to the cloud:", leaks.len());
    for l in leaks.iter().take(8) {
        println!("  - {l}");
    }
    assert_eq!(leaks.len(), 4 * cfg.layers);
    println!("\n(compare: Centaur's leak list is empty — run `cargo run --example quickstart`)");
    Ok(())
}
