//! **End-to-end serving driver** (the mandated E2E validation): load the
//! build-time-trained tiny BERT classifier, serve a batch of real test-set
//! requests through the full stack — coordinator → dynamic batcher →
//! Centaur three-party protocol engine (optionally the XLA/PJRT backend
//! executing the AOT Pallas artifacts) — and report task accuracy,
//! latency percentiles, throughput, and communication totals.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_batch -- [--requests 64] [--backend xla]
//! ```
//! Results are recorded in EXPERIMENTS.md §E2E.

use centaur::coordinator::{Coordinator, ServerConfig};
use centaur::data::{artifacts_dir, TaskData, Vocab};
use centaur::model::ModelWeights;
use centaur::net::NetworkProfile;
use centaur::util::cli::Args;

fn main() -> centaur::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let dir = args.opt_or("artifacts", &artifacts_dir()).to_string();
    let task = args.opt_or("task", "qnli").to_string();
    let n_req = args.opt_usize("requests", 48);
    let backend = args.opt_or("backend", "native").to_string();

    // Load the trained model + dataset produced by `make artifacts`.
    let (cfg, weights) = ModelWeights::load_tag(&dir, &format!("bert-tiny-{task}"))?;
    let td = TaskData::load(&dir, &task)?;
    let vocab = Vocab::load(&dir)?;
    println!(
        "loaded bert-tiny-{task}: {} params, vocab {}, {} test examples",
        cfg.param_count(),
        vocab.len(),
        td.test.ids.len()
    );

    let mut sc = ServerConfig::new(cfg.clone(), weights);
    sc.backend = backend.clone();
    sc.artifacts_dir = dir.clone();
    sc.profile = NetworkProfile::by_name(args.opt_or("net", "lan")).unwrap();
    sc.max_batch = args.opt_usize("batch", 8);
    sc.workers = args.opt_usize("workers", 1);
    println!(
        "coordinator: backend={} batch<={} workers={} net={}",
        backend, sc.max_batch, sc.workers, sc.profile.name
    );

    let coord = Coordinator::start(sc)?;
    let t0 = std::time::Instant::now();
    let reqs: Vec<(Vec<u32>, f32)> = td
        .test
        .ids
        .iter()
        .cloned()
        .zip(td.test.labels.iter().copied())
        .take(n_req)
        .collect();
    let rxs: Vec<_> = reqs.iter().map(|(ids, _)| coord.submit(ids.clone())).collect();

    let mut hits = 0usize;
    for (rx, (_, label)) in rxs.into_iter().zip(&reqs) {
        let resp = rx.recv().expect("coordinator alive")?;
        let pred = resp
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if pred == *label as usize {
            hits += 1;
        }
    }
    let wall = t0.elapsed();
    let snap = coord.shutdown();

    println!("\n== E2E results ==");
    println!("accuracy over served requests: {:.1}% ({hits}/{})", 100.0 * hits as f64 / reqs.len() as f64, reqs.len());
    println!("{}", snap.summary());
    println!("wall time: {}", centaur::util::human_secs(wall.as_secs_f64()));
    assert!(hits * 100 >= reqs.len() * 60, "served accuracy suspiciously low");
    println!("serve_batch OK");
    Ok(())
}
