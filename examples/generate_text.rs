//! Private text generation (paper §1 motivation: SMPC GPT-2 takes 25+
//! minutes per token; Centaur brings private NLG into interactive range).
//! Decodes **incrementally** over the secret-shared KV cache: after a
//! cold prefill of the prompt, every token is a single-token three-party
//! forward, streamed as the protocol produces it, with the cold-prefill /
//! warm-decode communication split reported at the end.
//!
//! ```bash
//! make artifacts && cargo run --release --example generate_text -- --steps 8
//! ```
//!
//! Without artifacts (e.g. CI) it falls back to random gpt2-tiny weights —
//! the decode protocol is exercised end-to-end, tokens print as raw ids.

use centaur::data::{artifacts_dir, Vocab, CLS};
use centaur::engine::CentaurEngine;
use centaur::model::{ModelConfig, ModelWeights};
use centaur::net::NetworkProfile;
use centaur::util::cli::Args;
use centaur::util::{human_bytes, human_secs};

fn main() -> centaur::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let dir = args.opt_or("artifacts", &artifacts_dir()).to_string();
    let steps = args.opt_usize("steps", 8);
    let prompt_text = args.opt_or("prompt", "on 6 january 1854 the ottoman forces at").to_string();

    // Trained checkpoint + vocab when artifacts exist; random-weight
    // protocol smoke mode otherwise (CI runs without `make artifacts`).
    let (cfg, w, vocab) = match (ModelWeights::load_tag(&dir, "gpt2-tiny-wikitext103"), Vocab::load(&dir)) {
        (Ok((cfg, w)), Ok(v)) => (cfg, w, Some(v)),
        _ => {
            eprintln!("artifacts missing — falling back to random gpt2-tiny weights (smoke mode)");
            let cfg = ModelConfig::gpt2_tiny();
            let w = ModelWeights::random(&cfg, 7);
            (cfg, w, None)
        }
    };
    let prompt: Vec<u32> = match &vocab {
        Some(v) => {
            let mut ids = vec![CLS];
            ids.extend(prompt_text.split_whitespace().map(|t| v.id(t)));
            ids
        }
        None => vec![CLS, 7, 11, 13],
    };
    // In smoke mode the English prompt was never tokenized — show the ids
    // actually fed to the protocol instead.
    let prompt_shown = match &vocab {
        Some(_) => prompt_text.clone(),
        None => prompt.iter().map(|t| format!("<{t}>")).collect::<Vec<_>>().join(" "),
    };
    println!("prompt : {prompt_shown}");

    let profile = NetworkProfile::by_name(args.opt_or("net", "wan1")).unwrap();
    let mut engine = CentaurEngine::new(&cfg, &w, profile, 7)?;
    let t0 = std::time::Instant::now();
    let out = engine.generate_streaming(&prompt, steps, &mut |i, tok, step| {
        let word = vocab.as_ref().map(|v| v.decode(&[tok])).unwrap_or_else(|| format!("<{tok}>"));
        println!(
            "  token[{i}] = {word:<16} {} online, {} simulated",
            human_bytes(step.bytes_total()),
            human_secs(step.total_time(&profile)),
        );
        true
    })?;
    let decoded = match &vocab {
        Some(v) => v.decode(&out.tokens),
        None => out.tokens.iter().map(|t| format!("<{t}>")).collect::<Vec<_>>().join(" "),
    };
    println!("output : {prompt_shown} | {decoded}");

    let per_tok = out.decode.bytes_total() / steps.max(1) as u64;
    println!(
        "\ncorr setup: {} | cold prefill ({} tokens): {} | warm decode ({} tokens): {} ({} per token)",
        human_bytes(out.setup.bytes_total()),
        prompt.len(),
        human_bytes(out.prefill.bytes_total()),
        steps,
        human_bytes(out.decode.bytes_total()),
        human_bytes(per_tok),
    );
    println!(
        "per-token simulated {} under {} ({} local compute total)",
        human_secs(out.decode.total_time(&profile) / steps.max(1) as f64),
        profile.name,
        human_secs(t0.elapsed().as_secs_f64()),
    );
    assert!(engine.leaks().is_empty());
    println!("generate_text OK");
    Ok(())
}
