//! Private text generation (paper §1 motivation: SMPC GPT-2 takes 25+
//! minutes per token; Centaur brings private NLG into interactive range).
//! Loads the trained tiny GPT-2 and greedily decodes a continuation with
//! every forward pass running through the three-party protocol.
//!
//! ```bash
//! make artifacts && cargo run --release --example generate_text -- --steps 8
//! ```

use centaur::data::{artifacts_dir, Vocab};
use centaur::engine::CentaurEngine;
use centaur::model::ModelWeights;
use centaur::net::NetworkProfile;
use centaur::util::cli::Args;

fn main() -> centaur::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let dir = args.opt_or("artifacts", &artifacts_dir()).to_string();
    let steps = args.opt_usize("steps", 8);
    let vocab = Vocab::load(&dir)?;
    let (cfg, w) = ModelWeights::load_tag(&dir, "gpt2-tiny-wikitext103")?;
    let prompt_text = args.opt_or("prompt", "on 6 january 1854 the ottoman forces at");
    let prompt = {
        let mut ids = vec![centaur::data::CLS];
        ids.extend(prompt_text.split_whitespace().map(|t| vocab.id(t)));
        ids
    };
    println!("prompt : {prompt_text}");

    let profile = NetworkProfile::by_name(args.opt_or("net", "wan1")).unwrap();
    let mut engine = CentaurEngine::new(&cfg, &w, profile, 7)?;
    let t0 = std::time::Instant::now();
    let (generated, cost) = engine.generate(&prompt, steps)?;
    println!("output : {prompt_text} | {}", vocab.decode(&generated));
    println!(
        "\n{} tokens, comm {} total, simulated {} per token under {} ({} local compute)",
        steps,
        centaur::util::human_bytes(cost.bytes_total()),
        centaur::util::human_secs(cost.total_time(&profile) / steps as f64),
        profile.name,
        centaur::util::human_secs(t0.elapsed().as_secs_f64()),
    );
    assert!(engine.leaks().is_empty());
    println!("generate_text OK");
    Ok(())
}
