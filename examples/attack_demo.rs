//! Attack demo (paper Fig. 4): train a SIP inversion model on an auxiliary
//! corpus, then try to reconstruct private sentences from (a) the plaintext
//! `QKᵀ` a permutation-only PPTI exposes and (b) the `O1π₁` Centaur's cloud
//! party actually sees. Prints recovered text side by side.
//!
//! ```bash
//! make artifacts && cargo run --release --example attack_demo
//! ```

use centaur::data::{artifacts_dir, AttackCorpora, Vocab};
use centaur::model::ModelWeights;
use centaur::util::cli::Args;

fn main() -> centaur::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let dir = args.opt_or("artifacts", &artifacts_dir()).to_string();
    let examples = args.opt_usize("examples", 3);

    let vocab = Vocab::load(&dir)?;
    let corpora = AttackCorpora::load(&dir)?;
    let (cfg, w) = ModelWeights::load_tag(&dir, "gpt2-tiny-wikitext103")?;
    let aux: Vec<Vec<u32>> = corpora.aux_indist.iter().take(600).cloned().collect();

    println!("attacker: SIP inversion model trained on {} in-distribution auxiliary sentences", aux.len());
    println!("target  : first-layer attention scores (O1 = QKᵀ/√dh)\n");
    for (i, victim) in corpora.private.iter().take(examples).enumerate() {
        let (truth, plain, perm) = centaur::attacks::harness::recovery_example(
            &cfg,
            &w,
            &aux,
            victim,
            &vocab,
            0xDE40 + i as u64,
        )?;
        println!("---- example {i} ----");
        println!("ground truth          : {truth}");
        println!("recovered (plain O1)  : {plain}");
        println!("recovered (Centaur O1π₁): {perm}\n");
        let truth_toks: Vec<&str> = truth.split(' ').collect();
        let rec_toks: Vec<&str> = plain.split(' ').collect();
        let overlap = rec_toks.iter().filter(|t| truth_toks.contains(t)).count();
        assert!(overlap * 2 >= truth_toks.len(), "plaintext attack should recover most tokens");
    }
    println!("attack_demo OK — permuted observations yield garbled output");
    Ok(())
}
