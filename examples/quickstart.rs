//! Quickstart: one private inference end-to-end on a tiny model, with a
//! plaintext cross-check and the communication ledger.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use centaur::engine::CentaurEngine;
use centaur::model::{forward, ModelConfig, ModelWeights, Variant};
use centaur::net::NetworkProfile;

fn main() -> centaur::Result<()> {
    // 1. Model developer side: a BERT-tiny with (here) random weights.
    let cfg = ModelConfig::bert_tiny();
    let weights = ModelWeights::random(&cfg, 42);
    println!("model: {} ({} parameters)", cfg.name, cfg.param_count());

    // 2. Initialization: draw permutations, permute parameters, deal the
    //    shared permutation matrices — all inside the engine constructor.
    let mut engine = CentaurEngine::new(&cfg, &weights, NetworkProfile::wan1(), 7)?;
    println!("permuted parameters shipped to P1: {}", centaur::util::human_bytes(engine.init_param_bytes()));

    // 3. Client side: a (padded) token sequence.
    let tokens: Vec<u32> = (0..cfg.n_ctx as u32).map(|i| 4 + (i * 37) % 500).collect();

    // 4. Private inference across P0/P1/P2.
    let out = engine.infer(&tokens)?;
    println!("\nprivate logits : {:?}", out.logits.row(0));

    // 5. Cross-check against plaintext inference (paper: identical
    //    performance — Centaur computes the exact model).
    let plain = forward(&cfg, &weights, &tokens, Variant::Exact);
    println!("plaintext      : {:?}", plain.row(0));
    println!("max |diff|     : {:.6}", out.logits.max_abs_diff(&plain));

    // 6. What it cost, and what the cloud saw.
    println!("\ncommunication breakdown (WAN 200Mbps/40ms):");
    println!("{}", out.stats.breakdown(&NetworkProfile::wan1()));
    println!("unpermuted plaintext seen by P1: {:?} (must be empty)", engine.leaks());
    assert!(engine.leaks().is_empty());
    assert!(out.logits.max_abs_diff(&plain) < 0.05);
    println!("quickstart OK");
    Ok(())
}
