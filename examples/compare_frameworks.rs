//! Framework comparison on one model: communication volume and simulated
//! wall time for Centaur vs the SMPC baselines and permutation-only PPTI
//! (a compact, runnable slice of the paper's Figs. 7/8).
//!
//! ```bash
//! cargo run --release --example compare_frameworks -- [--model bert-tiny] [--full]
//! ```

use centaur::baselines::FrameworkKind;
use centaur::model::ModelConfig;
use centaur::net::NetworkProfile;
use centaur::report::measure_framework;
use centaur::util::cli::Args;
use centaur::util::{human_bytes, human_secs};

fn main() -> centaur::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.opt_or("model", "bert-tiny");
    let cfg = ModelConfig::by_name(model).ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
    let extrapolate = !args.flag("full");
    println!(
        "{model}: d={} h={} layers={} n={} ({} params)\n",
        cfg.d, cfg.h, cfg.layers, cfg.n_ctx, cfg.param_count()
    );
    println!(
        "{:<12} {:>12} {:>8} {:>12} {:>12} {:>12}",
        "framework", "comm", "rounds", "LAN", "WAN1", "WAN2"
    );
    let mut centaur_bytes = 0u64;
    for kind in FrameworkKind::ALL {
        let ledger = measure_framework(kind, &cfg, 77, extrapolate)?;
        if kind == FrameworkKind::Centaur {
            centaur_bytes = ledger.bytes_total();
        }
        println!(
            "{:<12} {:>12} {:>8} {:>12} {:>12} {:>12}",
            kind.name(),
            human_bytes(ledger.bytes_total()),
            ledger.rounds_total(),
            human_secs(ledger.total_time(&NetworkProfile::lan())),
            human_secs(ledger.total_time(&NetworkProfile::wan1())),
            human_secs(ledger.total_time(&NetworkProfile::wan2())),
        );
    }
    println!("\n(SMPC baselines vs Centaur comm ratio drives the paper's 5.0-30.4x speedups;");
    println!(" PermOnly is near-plaintext but leaks intermediates — see attack_demo.)");
    assert!(centaur_bytes > 0);
    println!("compare_frameworks OK");
    Ok(())
}
